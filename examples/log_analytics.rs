//! Log analytics — the kind of "smaller Big Data job" the paper's intro
//! motivates (most cloud jobs fit one node; Appuswamy et al. [1]) — on
//! the **lazy `Dataset` dataflow surface**.
//!
//! ```bash
//! cargo run --release --example log_analytics
//! ```
//!
//! One `Runtime` session, several plans over synthetic web-server logs
//! (as a long-lived application would — one pool, one agent):
//!
//! 1. status-code counts — sum reducer → combining flow;
//! 2. per-endpoint worst latency — max reducer → combining flow;
//! 3. mean latency via the declarative reducer DSL;
//! 4. a **multi-stage plan**: status counts → filter → status-class
//!    rollup, recorded lazily; the whole-plan pass fuses the filter into
//!    the second map phase and streams the first stage's shards straight
//!    into the second stage's splitter — zero materialized intermediates;
//! 5. a session-dedup job whose reducer has an early exit → the agent
//!    *rejects* it and the reduce flow runs (transparently, correctly);
//! 6. the same status count fed from a **streaming source** (chunked
//!    generator) — identical results without materializing the input.

use mr4r::api::config::OptimizeMode;
use mr4r::api::reducers::RirReducer;
use mr4r::api::{ChunkedSource, Emitter, JobConfig, KeyValue, Runtime};
use mr4r::optimizer::ast::specs;
use mr4r::optimizer::builder::canon;
use mr4r::util::prng::Xoshiro256;

/// One synthetic access-log line: "METHOD /path STATUS LATENCY_MS".
fn synth_logs(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Xoshiro256::seeded(seed);
    let endpoints = [
        "/api/users", "/api/orders", "/api/search", "/static/app.js", "/health",
    ];
    let statuses = [200u32, 200, 200, 200, 301, 404, 500];
    (0..n)
        .map(|_| {
            let ep = rng.pick(&endpoints);
            let st = rng.pick(&statuses);
            let lat = (rng.unit_f64() * rng.unit_f64() * 900.0 + 1.0) as u64;
            format!("GET {ep} {st} {lat}")
        })
        .collect()
}

fn main() {
    let logs = synth_logs(200_000, 7);
    let rt = Runtime::with_config(JobConfig::fast());

    // --- Plan 1: requests per status code (sum → optimizable) ---
    let status_mapper = |line: &String, em: &mut dyn Emitter<i64, i64>| {
        let mut it = line.split(' ');
        let status: i64 = it.nth(2).and_then(|s| s.parse().ok()).unwrap_or(0);
        em.emit(status, 1);
    };
    let by_status = rt
        .dataset(&logs)
        .map_reduce(
            status_mapper,
            RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_count")),
        )
        .collect_sorted();
    println!("requests by status ({} flow):", by_status.metrics().flow.label());
    for kv in &by_status.items {
        println!("  {}  {:>7}", kv.key, kv.value);
    }
    let flow1 = by_status.metrics().flow.label();

    // --- Plan 2: worst latency per endpoint (max → optimizable) ---
    let latency_mapper = |line: &String, em: &mut dyn Emitter<String, i64>| {
        let mut it = line.split(' ');
        let ep = it.nth(1).unwrap_or("?").to_string();
        let lat: i64 = it.nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
        em.emit(ep, lat);
    };
    let worst = rt
        .dataset(&logs)
        .map_reduce(
            latency_mapper,
            RirReducer::<String, i64>::new(canon::max_i64("logs.worst_latency")),
        )
        .collect();
    let mut worst_pairs = worst.items.clone();
    worst_pairs.sort_by(|a, b| b.value.cmp(&a.value));
    println!("\nworst latency per endpoint ({} flow):", worst.metrics().flow.label());
    for kv in &worst_pairs {
        println!("  {:>5}ms  {}", kv.value, kv.key);
    }
    let flow2 = worst.metrics().flow.label();

    // --- Plan 2b: mean latency per endpoint, written in the declarative
    // reducer DSL (compiled to RIR, then transformed to a combiner —
    // semantic information flowing from the API down, paper §6) ---
    let mean_mapper = |line: &String, em: &mut dyn Emitter<String, f64>| {
        let mut it = line.split(' ');
        let ep = it.nth(1).unwrap_or("?").to_string();
        let lat: f64 = it.nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);
        em.emit(ep, lat);
    };
    let means = rt
        .dataset(&logs)
        .map_reduce(
            mean_mapper,
            RirReducer::<String, f64>::new(
                specs::mean_f64("logs.mean_latency").compile().expect("spec compiles"),
            ),
        )
        .collect();
    let mut mean_pairs = means.items.clone();
    mean_pairs.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
    println!(
        "\nmean latency per endpoint ({} flow, DSL-compiled reducer):",
        means.metrics().flow.label()
    );
    for kv in &mean_pairs {
        println!("  {:>7.1}ms  {}", kv.value, kv.key);
    }
    assert_eq!(means.metrics().flow.label(), "combine");

    // --- Plan 3: the multi-stage lazy plan. Status counts → drop the
    // healthy 2xx bulk → roll up by status class, recorded as ONE plan.
    // Nothing runs until collect(); the whole-plan pass then fuses the
    // filter into stage 2's mapper and streams stage 1's shard outputs
    // straight into stage 2's splitter — no JobOutput round-trip.
    let error_classes = rt
        .dataset(&logs)
        .map_reduce(
            status_mapper,
            RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_count")),
        )
        .filter(|kv: &KeyValue<i64, i64>| kv.key >= 300)
        .map_reduce(
            |kv: &KeyValue<i64, i64>, em: &mut dyn Emitter<i64, i64>| {
                em.emit(kv.key / 100, kv.value);
            },
            RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_class")),
        )
        .collect_sorted();
    println!("\nnon-2xx requests by status class (one lazy 2-stage plan):");
    for kv in &error_classes.items {
        println!("  {}xx  {:>7}", kv.key, kv.value);
    }
    println!(
        "  plan: {} fused op(s), {} streamed handoff(s), {} materialized intermediates",
        error_classes.report.fused_ops,
        error_classes.report.streamed_handoffs,
        error_classes.report.materialized_pairs,
    );
    assert_eq!(error_classes.report.fused_ops, 1);
    assert_eq!(error_classes.report.streamed_handoffs, 1);
    assert_eq!(error_classes.report.materialized_pairs, 0);

    // The same plan with the optimizer off runs eagerly: every stage
    // boundary materializes, and the report shows the round-trips.
    let eager = rt
        .dataset(&logs)
        .optimize(OptimizeMode::Off)
        .map_reduce(
            status_mapper,
            RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_count")),
        )
        .filter(|kv: &KeyValue<i64, i64>| kv.key >= 300)
        .map_reduce(
            |kv: &KeyValue<i64, i64>, em: &mut dyn Emitter<i64, i64>| {
                em.emit(kv.key / 100, kv.value);
            },
            RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_class")),
        )
        .collect_sorted();
    assert_eq!(eager.items, error_classes.items, "plan rewrites change nothing");
    assert!(eager.report.materialized_pairs > 0);
    println!(
        "  (optimizer off: {} materialized intermediates, same results)",
        eager.report.materialized_pairs
    );

    // --- Plan 4: a non-transformable reducer (early exit) ---
    let first_burst = rt
        .dataset(&logs)
        .map_reduce(
            status_mapper,
            RirReducer::<i64, i64>::new(canon::early_exit("logs.first_burst")),
        )
        .collect();
    println!(
        "\nnon-fold reducer: flow={} (agent said: {})",
        first_burst.metrics().flow.label(),
        first_burst
            .metrics()
            .fallback_reason
            .as_deref()
            .unwrap_or("-")
    );
    let flow3 = first_burst.metrics().flow.label();

    // --- Plan 1c: streaming source — same counts without a materialized
    // input slice (chunks generated on demand) ---
    let mut served = 0usize;
    let logs_for_stream = logs.clone();
    let stream = ChunkedSource::new(move || {
        if served >= logs_for_stream.len() {
            return None;
        }
        let end = (served + 8192).min(logs_for_stream.len());
        let chunk = logs_for_stream[served..end].to_vec();
        served = end;
        Some(chunk)
    });
    let streamed = rt
        .dataset(stream)
        .map_reduce(
            status_mapper,
            RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_count")),
        )
        .collect_sorted();
    assert_eq!(
        streamed.items, by_status.items,
        "streaming source must match the materialized run"
    );
    println!("\nstreamed status counts match materialized run: true");

    let stats = rt.agent().stats();
    println!(
        "\nsession: {} threads spawned once; agent: {} classes optimized, {} rejected, \
         {} cache hits, {} whole-plan passes ({} ops fused, {} handoffs streamed)",
        rt.spawned_threads(),
        stats.optimized,
        stats.rejected,
        stats.cache_hits,
        stats.plans,
        stats.fused_stages,
        stats.streamed_handoffs
    );
    assert_eq!(flow1, "combine");
    assert_eq!(flow2, "combine");
    assert_eq!(flow3, "reduce");
    assert!(stats.cache_hits >= 2, "repeated classes must hit the cache");
    assert!(stats.plans >= 7, "every collect runs the whole-plan pass");
}
