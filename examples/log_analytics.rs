//! Streaming log analytics — rolling per-minute metrics over an
//! unbounded access-log feed, the continuous version of the "smaller
//! Big Data job" the paper's intro motivates (most cloud jobs fit one
//! node; Appuswamy et al.).
//!
//! ```bash
//! cargo run --release --example log_analytics
//! ```
//!
//! A live [`StreamSource`] is fed chunk-by-chunk through its push
//! handle while a standing query aggregates tumbling 1-minute windows
//! per endpoint: request count, error count, worst latency. The
//! per-window rollup is a declared associative + commutative
//! [`Aggregator`] with a mergeable holder, so the window engine folds
//! each event into its pane holder once and *merges* holders at fire —
//! the paper's combining flow extended across event time (no buffered
//! re-reduce). The batch twin (`Dataset::keyed().window_tumbling()`)
//! runs the same plan over the materialized log and must agree window
//! for window.

use mr4r::api::keyed::Aggregator;
use mr4r::api::JobConfig;
use mr4r::util::prng::Xoshiro256;
use mr4r::{Runtime, StreamSource, WindowResult};

/// One parsed event: `(ts, (latency_ms, is_error))`.
type Ev = (u64, (i64, i64));

/// Per-`(window, endpoint)` rollup: `(requests, worst_latency, errors)`.
/// Declared associative + commutative with a mergeable holder — pane
/// holders add component-wise, so overlapping/fired windows never
/// re-fold raw events.
struct Rollup;

impl Aggregator<Ev, (i64, i64, i64), (i64, i64, i64)> for Rollup {
    const ASSOCIATIVE: bool = true;
    const COMMUTATIVE: bool = true;
    const MERGEABLE: bool = true;

    fn init(&self) -> (i64, i64, i64) {
        (0, 0, 0)
    }

    fn combine(&self, holder: &mut (i64, i64, i64), value: Ev) {
        let (lat, is_err) = value.1;
        holder.0 += 1;
        holder.1 = holder.1.max(lat);
        holder.2 += is_err;
    }

    fn finish(&self, holder: (i64, i64, i64)) -> (i64, i64, i64) {
        holder
    }

    fn merge_holders(&self, into: &mut (i64, i64, i64), other: (i64, i64, i64)) {
        into.0 += other.0;
        into.1 = into.1.max(other.1);
        into.2 += other.2;
    }

    fn name(&self) -> &str {
        "logs.endpoint_rollup"
    }
}

/// Synthetic access-log lines `"TS /path STATUS LATENCY_MS"`, ~250
/// requests per tick so `n` events span `n / (250 * 60)` minutes.
fn synth_logs(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Xoshiro256::seeded(seed);
    let endpoints = [
        "/api/users", "/api/orders", "/api/search", "/static/app.js", "/health",
    ];
    let statuses = [200u32, 200, 200, 200, 301, 404, 500];
    (0..n)
        .map(|i| {
            let ts = (i / 250) as u64;
            let ep = rng.pick(&endpoints);
            let st = rng.pick(&statuses);
            let lat = (rng.unit_f64() * rng.unit_f64() * 900.0 + 1.0) as u64;
            format!("{ts} {ep} {st} {lat}")
        })
        .collect()
}

/// `"TS /path STATUS LATENCY_MS"` → `(endpoint, (ts, (lat, is_err)))`.
fn parse(line: &str) -> (String, Ev) {
    let mut it = line.split(' ');
    let ts: u64 = it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    let ep = it.next().unwrap_or("?").to_string();
    let status: i64 = it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    let lat: i64 = it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
    (ep, (ts, (lat, i64::from(status >= 500))))
}

fn print_window(w: &WindowResult<String, (i64, i64, i64)>) {
    let mut rows = w.pairs.clone();
    rows.sort_by(|a, b| b.value.0.cmp(&a.value.0).then_with(|| a.key.cmp(&b.key)));
    println!("minute {:>2} [{:>4}..{:>4}):", w.window, w.start, w.end);
    for p in &rows {
        println!(
            "  {:<16} {:>6} req  {:>3} err  worst {:>4}ms",
            p.key, p.value.0, p.value.2, p.value.1
        );
    }
}

fn main() {
    let logs = synth_logs(120_000, 7);
    let rt = Runtime::with_config(JobConfig::fast().with_threads(4));

    // The standing query: parse → key by endpoint → tumbling 1-minute
    // (60-tick) windows → mergeable rollup. Nothing runs yet; the plan
    // lowers once and waits on the feed.
    let (source, handle) = StreamSource::unbounded();
    let mut query = rt
        .stream(source)
        .map(|line: &String| parse(line))
        .keyed()
        .window_tumbling(60, |v: &Ev| v.0)
        .aggregate_by_key(Rollup);

    // Feed the live handle chunk-by-chunk, draining fired windows as
    // the event-time watermark passes each minute boundary — rolling
    // metrics, not an end-of-job report.
    let mut fired: Vec<WindowResult<String, (i64, i64, i64)>> = Vec::new();
    for chunk in logs.chunks(8_192) {
        handle.push(chunk.to_vec());
        if let Some(windows) = query.step() {
            for w in &windows {
                print_window(w);
            }
            fired.extend(windows);
        }
    }
    handle.close();

    // Drain whatever the close unblocked, then fire the tail window.
    let out = query.run_to_close();
    for w in &out.windows {
        print_window(w);
    }
    let metrics = out.metrics().clone();
    fired.extend(out.into_windows());

    println!(
        "\nstream: {} chunks, {} events, {} windows fired, {} pane holders merged, \
         {} elements re-folded, {} late",
        metrics.chunks_ingested,
        metrics.elements_ingested,
        metrics.windows_fired,
        metrics.holders_merged,
        metrics.elements_recomputed,
        metrics.late_elements,
    );
    assert!(metrics.merge_mode, "declared assoc+comm rollup must merge");
    assert!(metrics.holders_merged > 0);
    assert_eq!(metrics.elements_recomputed, 0, "merge path re-folds nothing");
    assert_eq!(metrics.late_elements, 0, "feed is in event-time order");
    assert_eq!(metrics.windows_fired as usize, fired.len());

    // The batch twin over the materialized log must agree pane for pane.
    let batch = rt
        .dataset(&logs)
        .map(|line: &String| parse(line))
        .keyed()
        .window_tumbling(60, |v: &Ev| v.0)
        .aggregate_by_key(Rollup);
    assert_eq!(fired.len(), batch.windows.len());
    for (s, b) in fired.iter().zip(&batch.windows) {
        assert_eq!((s.window, s.start, s.end), (b.window, b.start, b.end));
        let mut srows = s.pairs.clone();
        let mut brows = b.pairs.clone();
        srows.sort_by(|a, b| a.key.cmp(&b.key));
        brows.sort_by(|a, b| a.key.cmp(&b.key));
        assert_eq!(srows, brows, "minute {} must match the batch twin", s.window);
    }
    println!("batch twin agrees on all {} windows: true", fired.len());
}
