//! Log analytics — the kind of "smaller Big Data job" the paper's intro
//! motivates (most cloud jobs fit one node; Appuswamy et al. [1]).
//!
//! ```bash
//! cargo run --release --example log_analytics
//! ```
//!
//! Two MapReduce jobs over synthetic web-server logs sharing one optimizer
//! agent (as a long-lived application would):
//!
//! 1. status-code counts — sum reducer → combining flow;
//! 2. per-endpoint p-worst latency — max reducer → combining flow;
//! 3. a session-dedup job whose reducer has an early exit → the agent
//!    *rejects* it and the reduce flow runs (transparently, correctly).

use mr4r::api::reducers::RirReducer;
use mr4r::api::{Emitter, JobConfig, MapReduce};
use mr4r::optimizer::agent::OptimizerAgent;
use mr4r::optimizer::ast::specs;
use mr4r::optimizer::builder::canon;
use mr4r::util::prng::Xoshiro256;

/// One synthetic access-log line: "METHOD /path STATUS LATENCY_MS".
fn synth_logs(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Xoshiro256::seeded(seed);
    let endpoints = [
        "/api/users", "/api/orders", "/api/search", "/static/app.js", "/health",
    ];
    let statuses = [200u32, 200, 200, 200, 301, 404, 500];
    (0..n)
        .map(|_| {
            let ep = rng.pick(&endpoints);
            let st = rng.pick(&statuses);
            let lat = (rng.unit_f64() * rng.unit_f64() * 900.0 + 1.0) as u64;
            format!("GET {ep} {st} {lat}")
        })
        .collect()
}

fn main() {
    let logs = synth_logs(200_000, 7);
    let agent = OptimizerAgent::new();

    // --- Job 1: requests per status code (sum → optimizable) ---
    let status_mapper = |line: &String, em: &mut dyn Emitter<i64, i64>| {
        let mut it = line.split(' ');
        let status: i64 = it.nth(2).and_then(|s| s.parse().ok()).unwrap_or(0);
        em.emit(status, 1);
    };
    let job1 = MapReduce::new(
        status_mapper,
        RirReducer::<i64, i64>::new(canon::sum_i64("logs.status_count")),
    )
    .with_config(JobConfig::fast())
    .with_agent(agent.clone());
    let (mut by_status, r1) = job1.run_with_report(&logs);
    by_status.sort_by_key(|kv| kv.key);
    println!("requests by status ({} flow):", r1.metrics.flow.label());
    for kv in &by_status {
        println!("  {}  {:>7}", kv.key, kv.value);
    }

    // --- Job 2: worst latency per endpoint (max → optimizable) ---
    let latency_mapper = |line: &String, em: &mut dyn Emitter<String, i64>| {
        let mut it = line.split(' ');
        let ep = it.nth(1).unwrap_or("?").to_string();
        let lat: i64 = it.nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
        em.emit(ep, lat);
    };
    let job2 = MapReduce::new(
        latency_mapper,
        RirReducer::<String, i64>::new(canon::max_i64("logs.worst_latency")),
    )
    .with_config(JobConfig::fast())
    .with_agent(agent.clone());
    let (mut worst, r2) = job2.run_with_report(&logs);
    worst.sort_by(|a, b| b.value.cmp(&a.value));
    println!("\nworst latency per endpoint ({} flow):", r2.metrics.flow.label());
    for kv in &worst {
        println!("  {:>5}ms  {}", kv.value, kv.key);
    }

    // --- Job 2b: mean latency per endpoint, written in the declarative
    // reducer DSL (compiled to RIR, then transformed to a combiner —
    // semantic information flowing from the API down, paper §6) ---
    let mean_mapper = |line: &String, em: &mut dyn Emitter<String, f64>| {
        let mut it = line.split(' ');
        let ep = it.nth(1).unwrap_or("?").to_string();
        let lat: f64 = it.nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);
        em.emit(ep, lat);
    };
    let job2b = MapReduce::new(
        mean_mapper,
        RirReducer::<String, f64>::new(
            specs::mean_f64("logs.mean_latency").compile().expect("spec compiles"),
        ),
    )
    .with_config(JobConfig::fast())
    .with_agent(agent.clone());
    let (mut means, r2b) = job2b.run_with_report(&logs);
    means.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
    println!("\nmean latency per endpoint ({} flow, DSL-compiled reducer):", r2b.metrics.flow.label());
    for kv in &means {
        println!("  {:>7.1}ms  {}", kv.value, kv.key);
    }
    assert_eq!(r2b.metrics.flow.label(), "combine");

    // --- Job 3: a non-transformable reducer (early exit) ---
    let job3 = MapReduce::new(
        status_mapper,
        RirReducer::<i64, i64>::new(canon::early_exit("logs.first_burst")),
    )
    .with_config(JobConfig::fast())
    .with_agent(agent.clone());
    let (_, r3) = job3.run_with_report(&logs);
    println!(
        "\nnon-fold reducer: flow={} (agent said: {})",
        r3.metrics.flow.label(),
        r3.metrics.fallback_reason.as_deref().unwrap_or("-")
    );

    let stats = agent.stats();
    println!(
        "\nagent: {} classes optimized, {} rejected, detection {:.0}us/class",
        stats.optimized,
        stats.rejected,
        stats.detection.mean() * 1e6
    );
    assert_eq!(r1.metrics.flow.label(), "combine");
    assert_eq!(r2.metrics.flow.label(), "combine");
    assert_eq!(r3.metrics.flow.label(), "reduce");
}
