//! Clickstream join — the keyed dataset algebra end to end.
//!
//! ```bash
//! cargo run --release --example join_clickstream
//! ```
//!
//! Two sources — a clickstream of `(user, url)` events and a user table
//! of `(user, region)` rows — joined by user, re-keyed by region, and
//! aggregated with a *declared* associative merge. The run is repeated
//! with the optimizer off; both produce identical counts, and the
//! reports show what the declared channel saved: the combining run ships
//! one holder per key where the baseline ships every pair.

use mr4r::api::{JobConfig, OptimizeMode, Runtime};
use mr4r::optimizer::agent::CombinerSource;

/// Tiny deterministic LCG so the example needs no external data.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
}

fn main() {
    const USERS: usize = 200;
    const CLICKS: usize = 20_000;
    const REGIONS: [&str; 4] = ["eu", "us", "apac", "latam"];
    const PAGES: [&str; 5] = ["/home", "/search", "/item", "/cart", "/buy"];

    let mut rng = Lcg(42);
    let users: Vec<(String, String)> = (0..USERS)
        .map(|u| {
            let region = REGIONS[(rng.next() as usize) % REGIONS.len()];
            (format!("u{u:03}"), region.to_string())
        })
        .collect();
    // A quarter of the traffic comes from unknown users (no table row):
    // the inner join drops it, like any clickstream sessionization.
    let clicks: Vec<(String, String)> = (0..CLICKS)
        .map(|_| {
            let u = (rng.next() as usize) % (USERS + USERS / 3);
            let page = PAGES[(rng.next() as usize) % PAGES.len()];
            (format!("u{u:03}"), page.to_string())
        })
        .collect();

    let rt = Runtime::with_config(JobConfig::fast().with_threads(4));

    let run = |mode: OptimizeMode| {
        rt.dataset(&clicks)
            .optimize(mode)
            .keyed()
            .join(rt.dataset(&users).optimize(mode).keyed()) // (user, (url, region))
            .map(|kv| (kv.value.1.clone(), 1i64))
            .keyed()
            .reduce_by_key(|a, b| a + b) // declared associative sum
            .collect_sorted()
    };

    let optimized = run(OptimizeMode::Auto);
    let baseline = run(OptimizeMode::Off);
    assert_eq!(
        optimized.items, baseline.items,
        "declared combining must not change results"
    );

    println!("clicks per region (joined through {} users):", USERS);
    for kv in &optimized {
        println!("  {:>6}  {}", kv.value, kv.key);
    }

    let m_opt = optimized.metrics();
    let m_off = baseline.metrics();
    assert_eq!(m_opt.combiner_source, Some(CombinerSource::Declared));
    assert_eq!(m_off.combiner_source, None);
    assert!(m_opt.shuffled_holders < m_off.shuffled_pairs);
    assert!(m_opt.shuffled_bytes < m_off.shuffled_bytes);

    println!("\nfinal aggregate stage, optimizer auto vs off:");
    println!(
        "  auto : {} flow via {} channel — {} holders / {} bytes over the barrier",
        m_opt.flow.label(),
        m_opt.combiner_source.map_or("-", CombinerSource::label),
        m_opt.shuffled_holders,
        m_opt.shuffled_bytes,
    );
    println!(
        "  off  : {} flow — {} pairs / {} bytes over the barrier",
        m_off.flow.label(),
        m_off.shuffled_pairs,
        m_off.shuffled_bytes,
    );
    println!(
        "\nplan: {} stages measured, {} fused ops, {} streamed handoffs",
        optimized.report.stage_metrics.len(),
        optimized.report.fused_ops,
        optimized.report.streamed_handoffs,
    );
}
