//! Iterative analytics over a cached plan prefix.
//!
//! A driver loop re-derives per-user event counts from the raw log on
//! every round, then aggregates them differently each time (rising
//! thresholds). The expensive prefix — source scan + word-count-style
//! reduce — is identical across rounds, so it is marked with
//! `Dataset::cache()`: round 1 computes and stores it, rounds ≥ 2 read
//! it back from the session materialization cache. `Dataset::explain()`
//! shows the lowered plan, the cut point, and the prefix fingerprint
//! before anything runs.
//!
//! Run with: `cargo run --release --example cached_iterative`

use std::sync::Arc;

use mr4r::api::reducers::RirReducer;
use mr4r::api::traits::{Emitter, KeyValue, Mapper, Reducer};
use mr4r::optimizer::builder::canon;
use mr4r::{JobConfig, Runtime};

fn main() {
    let rt = Runtime::with_config(JobConfig::fast().with_threads(4));

    // The "log": one line per event, `user<i> item<j>` tokens.
    let logs: Vec<String> = (0..40_000)
        .map(|i| format!("user{} item{}", i % 97, i % 31))
        .collect();

    // Hoisted prefix closures: reusing these Arcs across rounds is what
    // makes every round's prefix fingerprint identical.
    let count_mapper: Arc<dyn Mapper<String, String, i64>> =
        Arc::new(|line: &String, em: &mut dyn Emitter<String, i64>| {
            for token in line.split_whitespace() {
                em.emit(token.to_string(), 1);
            }
        });
    let count_reducer: Arc<dyn Reducer<String, i64>> = Arc::new(RirReducer::<String, i64>::new(
        canon::sum_i64("cached.counts"),
    ));

    for round in 0..3i64 {
        let threshold = 100 * (round + 1);
        let prefix = rt
            .dataset(&logs)
            .tag("cached_iterative.logs")
            .map_reduce_shared(Arc::clone(&count_mapper), Arc::clone(&count_reducer))
            .cache();
        if round == 0 {
            println!("== lowered plan ==\n{}", prefix.explain());
        }
        // The per-round tail: histogram of counts above a rising
        // threshold (fresh closures — only the prefix is shared).
        let out = prefix
            .filter(move |kv: &KeyValue<String, i64>| kv.value >= threshold)
            .map_reduce(
                |kv: &KeyValue<String, i64>, em: &mut dyn Emitter<i64, i64>| {
                    em.emit(kv.value, 1)
                },
                RirReducer::<i64, i64>::new(canon::sum_i64("cached.hist")),
            )
            .collect_sorted();
        println!(
            "round {round}: {} distinct counts ≥ {threshold} | cache activity: \
             {} hit(s), {} miss(es), {} B inserted",
            out.len(),
            out.report.cache.hits,
            out.report.cache.misses,
            out.report.cache.bytes_inserted,
        );
        assert!(!out.is_empty(), "every threshold keeps some tokens");
    }

    let stats = rt.cache().stats();
    println!(
        "session cache: {} hit(s), {} miss(es), {} entr(ies), {} B cached, {} eviction(s)",
        stats.hits, stats.misses, stats.entries, stats.bytes_cached, stats.evictions
    );
    assert_eq!(stats.misses, 1, "the prefix must compute exactly once");
    assert_eq!(stats.hits, 2, "rounds 2 and 3 must reuse the cached counts");
    println!("ok: iterative rounds reused one materialized prefix");
}
