//! Streaming sessionization — sliding-window activity tracking over a
//! live clickstream.
//!
//! ```bash
//! cargo run --release --example streaming_sessionization
//! ```
//!
//! Two producers share a cloned [`StreamHandle`] and push interleaved
//! click chunks into one unbounded [`StreamSource`]; a standing query
//! counts clicks per user over **sliding** 5-minute windows advancing
//! every minute (size 300, slide 60, ticks = seconds). Each event lands
//! in one pane and is folded into its per-user count holder exactly
//! once; every window firing then *merges* the five pane holders it
//! covers — the overlap between adjacent windows costs holder merges,
//! never per-event recompute (the paper's combining flow extended
//! across event time). A user's per-window click count is their rolling
//! session intensity; users present in a window are its active
//! sessions.

use mr4r::api::JobConfig;
use mr4r::util::prng::Xoshiro256;
use mr4r::{Runtime, StreamSource, WindowResult};

/// One click: `(ts_seconds, user_id)`, event time non-decreasing.
fn synth_clicks(n: usize, seed: u64) -> Vec<(u64, u64)> {
    let mut rng = Xoshiro256::seeded(seed);
    let mut ts = 0u64;
    (0..n)
        .map(|_| {
            // ~2 clicks per second across ~40 intermittently active users.
            ts += u64::from(rng.below(2) == 0);
            let user = 100 + rng.below(40);
            (ts, user)
        })
        .collect()
}

fn print_window(w: &WindowResult<u64, i64>) {
    let clicks: i64 = w.pairs.iter().map(|p| p.value).sum();
    let top = w
        .pairs
        .iter()
        .max_by_key(|p| (p.value, std::cmp::Reverse(p.key)))
        .expect("fired windows are non-empty");
    println!(
        "window {:>2} [{:>4}s..{:>4}s): {:>2} active sessions, {:>4} clicks, \
         top user u{} ({} clicks)",
        w.window,
        w.start,
        w.end,
        w.pairs.len(),
        clicks,
        top.key,
        top.value
    );
}

fn main() {
    let clicks = synth_clicks(3_000, 23);
    let rt = Runtime::with_config(JobConfig::fast().with_threads(4));

    let (source, handle) = StreamSource::unbounded();
    let mut query = rt
        .stream(source)
        .keyed()
        .window_sliding(300, 60, |ts: &u64| *ts)
        .count_by_key();

    // Two producers (frontend + mobile, say) share the clone-able push
    // handle; the consumer steps the standing query after each push and
    // reports windows as the watermark crosses each minute boundary.
    let frontend = handle.clone();
    let mobile = handle;
    let mut fired: Vec<WindowResult<u64, i64>> = Vec::new();
    for (i, chunk) in clicks.chunks(250).enumerate() {
        let producer = if i % 2 == 0 { &frontend } else { &mobile };
        producer.push(chunk.iter().map(|&(ts, user)| (user, ts)).collect());
        if let Some(windows) = query.step() {
            for w in &windows {
                print_window(w);
            }
            fired.extend(windows);
        }
    }
    println!(
        "... feed live: watermark lag {}s, {} windows so far",
        query.metrics().watermark_lag,
        fired.len()
    );

    frontend.close(); // idempotent — closing either handle ends the feed
    let out = query.run_to_close();
    for w in &out.windows {
        print_window(w);
    }
    let metrics = out.metrics().clone();
    fired.extend(out.into_windows());

    println!(
        "\nstream: {} events over {} chunks, {} sliding windows, \
         {} pane holders merged (overlap paid in merges, 0 re-folds: {})",
        metrics.elements_ingested,
        metrics.chunks_ingested,
        metrics.windows_fired,
        metrics.holders_merged,
        metrics.elements_recomputed == 0,
    );
    assert!(metrics.merge_mode, "Count is declared assoc+comm+mergeable");
    assert_eq!(metrics.elements_recomputed, 0);
    assert_eq!(metrics.late_elements, 0);

    // Batch twin: the same clickstream as one bounded windowed plan.
    let pairs: Vec<(u64, u64)> = clicks.iter().map(|&(ts, user)| (user, ts)).collect();
    let batch = rt
        .dataset(&pairs)
        .keyed()
        .window_sliding(300, 60, |ts: &u64| *ts)
        .count_by_key();
    assert_eq!(fired.len(), batch.windows.len());
    for (s, b) in fired.iter().zip(&batch.windows) {
        assert_eq!((s.window, s.start, s.end), (b.window, b.start, b.end));
        let mut srows = s.pairs.clone();
        let mut brows = b.pairs.clone();
        srows.sort_by_key(|p| p.key);
        brows.sort_by_key(|p| p.key);
        assert_eq!(srows, brows, "window {} must match the batch twin", s.window);
    }
    println!("batch twin agrees on all {} windows: true", fired.len());
}
