//! K-Means pipeline — the paper's "challenge" benchmark end to end on a
//! `Runtime` session, with the numeric assignment running through the AOT
//! JAX/Pallas kernel when artifacts are built (`make artifacts`), native
//! Rust otherwise.
//!
//! ```bash
//! make artifacts && cargo run --release --example kmeans_pipeline
//! ```
//!
//! Demonstrates the combiner-with-state resolution the paper describes
//! (the emitted value is `[Σx, Σy, Σz, n]`, folded by the generated
//! vector-sum combiner, normalized outside the reduce) **and** the session
//! economics: every Lloyd iteration is a lazy `Dataset` plan
//! (`rt.dataset(blocks).map_reduce(..).collect()`) on one session, so all
//! iterations share one worker pool (threads spawn once) and one agent
//! (the reducer class transforms once, then every iteration — and every
//! whole-plan pass — is served from session state).

use mr4r::api::config::OptimizeMode;
use mr4r::api::{JobConfig, Runtime};
use mr4r::benchmarks::{datagen, kmeans, Backend};
use mr4r::util::timer::Stopwatch;

fn main() {
    let backend = Backend::auto();
    println!("backend: {}", backend.name());
    if matches!(backend, Backend::Native) {
        println!("(run `make artifacts` to route assignment through the Pallas kernel)");
    }

    let data = datagen::kmeans_points(0.02, 2024);
    println!(
        "{} points, {} initial centroids, {} Lloyd iterations",
        data.points.len(),
        data.initial_centroids.len(),
        kmeans::ITERATIONS
    );

    // One session for the whole driver: pool + agent persist across jobs.
    let rt = Runtime::with_config(JobConfig::fast().with_threads(4));
    let before = kmeans::mean_distance(&data, &data.initial_centroids, &backend);

    let sw = Stopwatch::start();
    let (centroids, metrics) =
        kmeans::run_mr4r(&data, &rt, &JobConfig::fast().with_threads(4), &backend);
    let optimized_secs = sw.secs();
    let after = kmeans::mean_distance(&data, &centroids, &backend);

    let sw = Stopwatch::start();
    let (centroids_off, _) = kmeans::run_mr4r(
        &data,
        &rt,
        &JobConfig::fast()
            .with_threads(4)
            .with_optimize(OptimizeMode::Off),
        &backend,
    );
    let unoptimized_secs = sw.secs();

    println!("\nclustering quality (mean point→centroid distance):");
    println!("  initial   : {before:.3}");
    println!("  converged : {after:.3}");
    println!("\nlast-iteration flow: {}", metrics.flow.label());
    println!("optimized run   : {optimized_secs:.3}s");
    println!("unoptimized run : {unoptimized_secs:.3}s");
    println!(
        "results equal   : {}",
        kmeans::digest_centroids(&centroids) == kmeans::digest_centroids(&centroids_off)
    );

    let stats = rt.agent().stats();
    println!(
        "\nsession: {} threads spawned once for {} plans; reducer class \
         transformed {} time(s), {} cache hits, {} whole-plan passes",
        rt.spawned_threads(),
        2 * kmeans::ITERATIONS,
        stats.optimized,
        stats.cache_hits,
        stats.plans
    );

    assert!(after < before, "Lloyd iterations must improve clustering");
    assert_eq!(
        kmeans::digest_centroids(&centroids),
        kmeans::digest_centroids(&centroids_off),
        "optimizer must not change results"
    );
    assert!(
        stats.cache_hits >= kmeans::ITERATIONS - 1,
        "iterations after the first must hit the per-class cache"
    );
}
