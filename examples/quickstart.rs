//! Quickstart — the paper's Figure 2 word count, on the session runtime.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the whole public API surface: a `Runtime` session, a mapper
//! closure, a reducer authored as an RIR program (one expression, like the
//! paper's anonymous class), and a `JobBuilder` with a sorted output sink.
//! The optimizer engages on its own — the report at the end shows the
//! combining flow was taken and the reduce phase never ran.

use mr4r::api::reducers::RirReducer;
use mr4r::api::{Emitter, JobConfig, Runtime};
use mr4r::optimizer::builder::canon;

fn main() {
    let corpus = vec![
        "the quick brown fox jumps over the lazy dog".to_string(),
        "the dog barks and the fox runs".to_string(),
        "a quick dog and a lazy fox".to_string(),
        "semantic information is inherent in parallel frameworks".to_string(),
        "the optimizer rewrites the reduce method into a combiner".to_string(),
    ];

    // One session: persistent worker pool + shared optimizer agent.
    let rt = Runtime::with_config(JobConfig::fast().with_threads(4));

    // Figure 2's Mapper: split, emit (word, 1).
    let mapper = |line: &String, em: &mut dyn Emitter<String, i64>| {
        for word in line.split_ascii_whitespace() {
            em.emit(word.to_ascii_uppercase(), 1);
        }
    };

    // Figure 2's Reducer, authored as an RIR program (the bytecode the
    // agent analyzes): acc = 0; for v in values { acc += v }; emit acc.
    let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("quickstart.sum"));

    // `.sorted()` picks the deterministic output sink.
    let out = rt.job(mapper, reducer).sorted().run(&corpus);

    let mut counts = out.pairs.clone();
    counts.sort_by(|a, b| b.value.cmp(&a.value).then(a.key.cmp(&b.key)));
    println!("top words:");
    for kv in counts.iter().take(8) {
        println!("  {:>3}  {}", kv.value, kv.key);
    }

    let m = out.metrics();
    println!("\nexecution flow : {} (optimizer engaged transparently)", m.flow.label());
    println!("map emits      : {} into {} keys", m.emits, m.keys);
    println!(
        "phases         : map {:.2}ms + finalize {:.2}ms (no reduce phase)",
        m.map_secs * 1e3,
        m.reduce_secs * 1e3
    );
    println!(
        "session        : {} worker threads spawned once, reused per job",
        rt.spawned_threads()
    );
    assert_eq!(m.flow.label(), "combine", "optimizer should engage");
}
