//! End-to-end driver — the full-system validation run recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end -- [scale]
//! ```
//!
//! Exercises all three layers on every benchmark of the suite:
//!   L1/L2  — AOT JAX/Pallas kernels executed via PJRT from the map phase
//!            (when artifacts are built; verified against native),
//!   L3     — the MR4R coordinator with the memsim heap, both execution
//!            flows, and both baselines,
//! and prints the paper's headline metrics: per-benchmark optimizer
//! speedup (claim: up to 2.0×, SM ≤ 1), gap to Phoenix++ (claim: ~17%),
//! and the WC GC-time collapse (Figs. 8/9 mechanism).
//!
//! Every MR4R run goes through the `Runtime` session path: each prepared
//! workload owns one session, so repeated measurement iterations reuse
//! one worker pool and hit the agent's per-class cache.

use mr4r::api::config::OptimizeMode;
use mr4r::benchmarks::suite::{prepare, BenchId, Framework, RunParams};
use mr4r::benchmarks::Backend;
use mr4r::harness::scaled_heap;
use mr4r::memsim::GcPolicy;
use mr4r::util::table::{f2, TextTable};
use mr4r::util::timer::{geomean, measure};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.004);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    // Timings use the native backend so all frameworks pay identical map
    // compute (Phoenix++'s HG path is per-pixel and never calls a kernel);
    // the PJRT backend then re-runs each workload to prove the three
    // layers compose and produce identical results.
    let backend = Backend::Native;
    let pjrt = match Backend::auto() {
        Backend::Pjrt(ks) => Some(Backend::Pjrt(ks)),
        Backend::Native => None,
    };
    println!(
        "end-to-end: scale={scale}, threads={threads}, timing backend=native, pjrt={}",
        if pjrt.is_some() { "verified" } else { "not built (make artifacts)" }
    );

    let (iters, warmup) = (3, 1);
    let mut table = TextTable::new(vec![
        "bench", "flow", "unopt(s)", "opt(s)", "speedup", "ppp(s)", "opt/ppp", "gc% unopt",
        "gc% opt",
    ]);
    let mut speedups = Vec::new();
    let mut vs_ppp = Vec::new();

    for id in BenchId::ALL {
        let w = prepare(id, scale, 42, backend.clone());

        let heap_u = scaled_heap(scale, GcPolicy::Parallel, 1.0);
        let unopt = measure(warmup, iters, || {
            w.run(
                Framework::Mr4r,
                &RunParams::fast(threads)
                    .with_optimize(OptimizeMode::Off)
                    .with_heap(heap_u.clone()),
            );
        })
        .mean();
        let gc_u = heap_u.stats();

        let heap_o = scaled_heap(scale, GcPolicy::Parallel, 1.0);
        let mut flow = String::new();
        let opt = measure(warmup, iters, || {
            let o = w.run(
                Framework::Mr4r,
                &RunParams::fast(threads).with_heap(heap_o.clone()),
            );
            flow = o.metrics.map(|m| m.flow.label().to_string()).unwrap_or_default();
        })
        .mean();
        let gc_o = heap_o.stats();

        let ppp = measure(warmup, iters, || {
            w.run(Framework::PhoenixPP, &RunParams::fast(threads));
        })
        .mean();

        // Digest equivalence across every engine, every run.
        let d_opt = w.run(Framework::Mr4r, &RunParams::fast(threads)).digest;
        let d_unopt = w
            .run(
                Framework::Mr4r,
                &RunParams::fast(threads).with_optimize(OptimizeMode::Off),
            )
            .digest;
        let d_ppp = w.run(Framework::PhoenixPP, &RunParams::fast(threads)).digest;
        let d_ph = w.run(Framework::Phoenix, &RunParams::fast(threads)).digest;
        assert_eq!(d_opt, d_unopt, "{}: optimizer changed results", id.code());
        assert_eq!(d_opt, d_ppp, "{}: phoenix++ result mismatch", id.code());
        assert_eq!(d_opt, d_ph, "{}: phoenix result mismatch", id.code());
        // Three-layer composition: same digest through the PJRT kernels.
        if let Some(pjrt_backend) = &pjrt {
            let wp = prepare(id, scale, 42, pjrt_backend.clone());
            let d_pjrt = wp.run(Framework::Mr4r, &RunParams::fast(threads)).digest;
            assert_eq!(d_opt, d_pjrt, "{}: pjrt result mismatch", id.code());
        }

        let speedup = unopt / opt;
        speedups.push(speedup);
        vs_ppp.push(ppp / opt);
        // GC share is per total accumulated run time across iterations.
        let gcpct = |gc: &mr4r::memsim::GcStats, total: f64| {
            100.0 * gc.gc_seconds / (total * (iters + warmup) as f64).max(1e-9)
        };
        table.row(vec![
            id.code().to_string(),
            flow.clone(),
            format!("{unopt:.3}"),
            format!("{opt:.3}"),
            f2(speedup),
            format!("{ppp:.3}"),
            f2(ppp / opt),
            f2(gcpct(&gc_u, unopt)),
            f2(gcpct(&gc_o, opt)),
        ]);
    }

    println!("\n{}", table.render());
    let max = speedups.iter().cloned().fold(0.0f64, f64::max);
    println!("headline: max optimizer speedup {max:.2}x (paper: up to 2.0x)");
    println!(
        "headline: optimized MR4R at {:.2}x of Phoenix++ geomean (paper: within 17%)",
        geomean(&vs_ppp)
    );
    println!(
        "all digests equal across frameworks, flows{} ✓",
        if pjrt.is_some() { ", and the PJRT kernel path" } else { "" }
    );
}
