"""L2 — the JAX compute graphs the coordinator's artifacts are lowered from.

For a data-pipeline paper the "model" is the per-benchmark numeric map
phase. Each exported graph wraps one L1 Pallas kernel (so the kernel
lowers into the same HLO module); `kmeans_step` additionally demonstrates
a *fused* L2 graph (assignment + segment-sum in one module) that the
optimizer-eliminated reduce phase corresponds to on the array side.

Exports (name -> (fn, example_args)) drive `aot.py`.
"""

import jax
import jax.numpy as jnp

from .kernels import SHAPES, histogram, kmeans, linreg, matmul, matmul_grid, pca


def matmul_tile(a, b):
    """MM benchmark map phase: one output tile partial product."""
    return matmul.matmul_tile(a, b)


def histogram_chunk(values):
    """HG benchmark map phase: per-chunk per-bin counts."""
    return histogram.histogram_chunk(values)


def kmeans_assign(points, centroids):
    """KM benchmark map phase: nearest-centroid assignment."""
    return kmeans.kmeans_assign(points, centroids)


def linreg_moments(xy):
    """LR chunked map phase: the five moment sums."""
    return linreg.linreg_moments(xy)


def pca_pair(rows):
    """PC benchmark map phase: covariance partials of one row pair."""
    return pca.pca_pair(rows)


def matmul_full(a, b):
    """Whole-matrix product on the Pallas 3-d grid schedule (512x512)."""
    return matmul_grid.matmul_grid(a, b)


def kmeans_step(points, centroids):
    """A fused Lloyd half-step: assign + per-cluster coordinate sums and
    counts, entirely on the array side.

    This is the L2 rendering of what the paper's optimizer does at L3:
    the per-point (key, value) emission plus reduce collapses into a
    segment-sum at emit time. Exported for the end-to-end example and the
    L2 fusion test; the MapReduce benchmarks intentionally do NOT use it
    (they exercise the coordinator's combine flow instead).
    """
    assign = kmeans.kmeans_assign(points, centroids).astype(jnp.int32)
    c = SHAPES["KM_CENTROIDS"]
    onehot = jax.nn.one_hot(assign, c, dtype=jnp.float32)  # (P, C)
    sums = jnp.dot(onehot.T, points, preferred_element_type=jnp.float32)
    counts = onehot.sum(axis=0)
    return sums, counts


def exports():
    """name -> (fn, example_args) for every AOT artifact."""
    return {
        "matmul": (matmul_tile, matmul.example_args()),
        "matmul_grid": (matmul_full, matmul_grid.example_args()),
        "histogram": (histogram_chunk, histogram.example_args()),
        "kmeans": (kmeans_assign, kmeans.example_args()),
        "linreg": (linreg_moments, linreg.example_args()),
        "pca": (pca_pair, pca.example_args()),
        "kmeans_step": (kmeans_step, kmeans.example_args()),
    }
