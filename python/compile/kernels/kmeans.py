"""KM assignment kernel — nearest centroid via the matmul expansion.

The GPU formulation loops centroids in shared memory per thread-block; the
TPU adaptation expands the squared distance as

    |p - c|^2 = |p|^2 - 2 p.c + |c|^2

so the (P, C) distance matrix is one MXU contraction (p @ c.T) plus rank-1
row/column corrections, then an argmin over the centroid axis. VMEM:
1024×128 f32 distances = 512 KiB + 1024×3 points + 128×3 centroids —
trivially resident.

Unused centroid slots are padded with huge coordinates by the caller, so
|c|^2 ≈ 1e60 keeps them out of every argmin.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import SHAPES

P = SHAPES["KM_POINTS"]
C = SHAPES["KM_CENTROIDS"]
D = SHAPES["KM_DIMS"]


def _kernel(p_ref, c_ref, o_ref):
    pts = p_ref[...]
    cents = c_ref[...]
    # -2 p.c term on the MXU; norms as rank-1 corrections.
    cross = jnp.dot(pts, cents.T, preferred_element_type=jnp.float32)
    cn = (cents * cents).sum(axis=1)
    # |p|^2 is constant per row — it cannot change the argmin, so skip it
    # (saves a broadcast; the distances are relative).
    d = cn[None, :] - 2.0 * cross
    o_ref[...] = jnp.argmin(d, axis=1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def kmeans_assign(points, centroids):
    """Nearest-centroid index (as f32) for each of P points."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((P,), jnp.float32),
        interpret=True,
    )(points, centroids)


def example_args():
    return (
        jax.ShapeDtypeStruct((P, D), jnp.float32),
        jax.ShapeDtypeStruct((C, D), jnp.float32),
    )
