"""L1 — Pallas kernels for the benchmark suite's numeric map phases.

Every kernel is written with ``interpret=True``: the CPU PJRT client the
Rust coordinator uses cannot execute Mosaic custom-calls, so the interpret
path is the execution vehicle while the kernel *structure* (BlockSpec
tiling, MXU-shaped contractions, VMEM-sized blocks) is authored for TPU.
See DESIGN.md §Hardware-Adaptation for the VMEM/MXU sizing notes.

Shape contract: ``SHAPES`` here must match
``rust/src/runtime/artifacts.rs::shapes``.
"""

SHAPES = {
    "MM_TILE": 128,
    "HG_CHUNK": 4096,
    "HG_BINS": 256,
    "KM_POINTS": 1024,
    "KM_CENTROIDS": 128,
    "KM_DIMS": 3,
    "LR_CHUNK": 4096,
    "PC_BLOCK": 512,
}

from . import histogram, kmeans, linreg, matmul, matmul_grid, pca, ref  # noqa: E402,F401
