"""LR moment kernel — the five regression sums in one pass.

(Sx, Sy, Sxx, Syy, Sxy) over a (CHUNK, 2) sample block. Pure VPU
reduction work (no MXU): one (CHUNK, 2) load from VMEM (32 KiB) and five
lane-reductions. Zero rows are the padding convention (they add nothing
to any moment).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import SHAPES

CHUNK = SHAPES["LR_CHUNK"]


def _kernel(xy_ref, o_ref):
    xy = xy_ref[...]
    x = xy[:, 0]
    y = xy[:, 1]
    o_ref[...] = jnp.stack(
        [x.sum(), y.sum(), (x * x).sum(), (y * y).sum(), (x * y).sum()]
    )


@functools.partial(jax.jit, static_argnames=())
def linreg_moments(xy):
    """Moment sums of one (CHUNK, 2) block."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((5,), jnp.float32),
        interpret=True,
    )(xy)


def example_args():
    return (jax.ShapeDtypeStruct((CHUNK, 2), jnp.float32),)
