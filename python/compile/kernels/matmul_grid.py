"""Grid-scheduled matmul — the full BlockSpec/grid Pallas pattern.

Where `matmul.py` is a single-tile contraction (the coordinator owns the
block schedule), this kernel expresses the whole (N, N) product *inside*
Pallas: a 3-d grid over (i, j, k) blocks with `BlockSpec` index maps
staging one A-tile and one B-tile into VMEM per step and accumulating the
output tile in place. This is the DESIGN.md §Hardware-Adaptation mapping
of a GPU threadblock schedule onto the TPU's HBM->VMEM pipeline: the
Mosaic compiler double-buffers the streamed tiles because consecutive k
steps touch disjoint HBM blocks.

VMEM per step: 3 x 128^2 f32 tiles = 192 KiB; the k-innermost grid order
keeps the output tile resident across the contraction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import SHAPES

T = SHAPES["MM_TILE"]
# Fixed AOT size: 4x4 blocks of 128 = 512x512 operands.
N = 4 * T


def _kernel(a_ref, b_ref, o_ref):
    # First k step of each (i, j) tile zeroes the accumulator.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def matmul_grid(a, b):
    """C = A @ B for (N, N) f32 operands, blocked (T, T) on a 3-d grid."""
    blocks = N // T
    return pl.pallas_call(
        _kernel,
        grid=(blocks, blocks, blocks),
        in_specs=[
            pl.BlockSpec((T, T), lambda i, j, k: (i, k)),
            pl.BlockSpec((T, T), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((T, T), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
        interpret=True,
    )(a, b)


def example_args():
    spec = jax.ShapeDtypeStruct((N, N), jnp.float32)
    return (spec, spec)
