"""Pure-jnp oracles — the correctness reference for every Pallas kernel.

These are the "obviously correct" formulations; pytest/hypothesis assert
``kernel(x) ~= ref(x)`` across random inputs and paddings.
"""

import jax.numpy as jnp


def matmul_tile(a, b):
    """C = A @ B for one (T, T) f32 tile pair."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def histogram_chunk(values, bins=256):
    """Per-bin counts of integer-valued f32 samples; values >= bins are
    padding and must not be counted."""
    idx = values.astype(jnp.int32)
    valid = (values >= 0) & (values < bins)
    return jnp.zeros((bins,), jnp.float32).at[jnp.where(valid, idx, 0)].add(
        valid.astype(jnp.float32)
    )


def kmeans_assign(points, centroids):
    """Nearest-centroid index (f32) per point, squared-L2 metric."""
    d = ((points[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    return jnp.argmin(d, axis=1).astype(jnp.float32)


def linreg_moments(xy):
    """(Sx, Sy, Sxx, Syy, Sxy) over an (N, 2) block."""
    x, y = xy[:, 0], xy[:, 1]
    return jnp.stack(
        [x.sum(), y.sum(), (x * x).sum(), (y * y).sum(), (x * y).sum()]
    )


def pca_pair(rows):
    """(Sa, Sb, Sab) over a (2, N) row-pair block."""
    a, b = rows[0], rows[1]
    return jnp.stack([a.sum(), b.sum(), (a * b).sum()])
