"""PC covariance-partial kernel.

(Sa, Sb, Sab) for one (2, BLOCK) row-pair block — the partials the PCA
benchmark's reduce phase sums per row pair. VPU reductions over a 4 KiB
block; zero columns are the padding convention.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import SHAPES

BLOCK = SHAPES["PC_BLOCK"]


def _kernel(r_ref, o_ref):
    rows = r_ref[...]
    a = rows[0]
    b = rows[1]
    o_ref[...] = jnp.stack([a.sum(), b.sum(), (a * b).sum()])


@functools.partial(jax.jit, static_argnames=())
def pca_pair(rows):
    """Covariance partials of one row-pair block."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        interpret=True,
    )(rows)


def example_args():
    return (jax.ShapeDtypeStruct((2, BLOCK), jnp.float32),)
