"""MM tile-matmul kernel — the MXU showcase.

One (128, 128) f32 × (128, 128) f32 tile product. 128 is the MXU systolic
array edge; three f32 tiles resident (A, B, C) cost 3 × 64 KiB = 192 KiB of
VMEM, far under the ~16 MiB budget, leaving room for double-buffering the
HBM→VMEM stream when the Rust coordinator sweeps k-blocks.

The grid is 1×1 on purpose: the *coordinator* owns the block schedule (it
is the MapReduce task structure of the MM benchmark), so the kernel is the
innermost tile contraction only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import SHAPES

T = SHAPES["MM_TILE"]


def _kernel(a_ref, b_ref, o_ref):
    # Single fused MXU contraction; preferred_element_type pins the f32
    # accumulator (bf16 inputs would still accumulate in f32 on TPU).
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def matmul_tile(a, b):
    """C = A @ B for one (T, T) tile pair (f32)."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((T, T), jnp.float32),
        interpret=True,
    )(a, b)


def example_args():
    spec = jax.ShapeDtypeStruct((T, T), jnp.float32)
    return (spec, spec)
