"""HG binning kernel — histogram as a one-hot contraction.

GPU histogram kernels scatter with atomics into shared-memory bins; TPUs
have no scatter-atomics, so the canonical adaptation (DESIGN.md
§Hardware-Adaptation) is a **one-hot matmul**: build the (CHUNK, BINS)
one-hot matrix of each sample's bin and contract the sample axis on the
MXU. VMEM: 4096×256 one-hot f32 = 4 MiB — inside budget; on real hardware
the one-hot would be bf16 (2 MiB) or int8.

Padding convention: values outside [0, BINS) contribute to no bin, so the
Rust side pads short chunks with 512.0.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import SHAPES

CHUNK = SHAPES["HG_CHUNK"]
BINS = SHAPES["HG_BINS"]


def _kernel(v_ref, o_ref):
    vals = v_ref[...]
    bins = jax.lax.broadcasted_iota(jnp.float32, (CHUNK, BINS), 1)
    onehot = (vals[:, None] == bins).astype(jnp.float32)
    # Contract the sample axis: ones(1, CHUNK) @ onehot → (1, BINS).
    ones = jnp.ones((1, CHUNK), jnp.float32)
    o_ref[...] = jnp.dot(ones, onehot, preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit, static_argnames=())
def histogram_chunk(values):
    """Counts per bin for one CHUNK of integer-valued f32 samples."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((BINS,), jnp.float32),
        interpret=True,
    )(values)


def example_args():
    return (jax.ShapeDtypeStruct((CHUNK,), jnp.float32),)
