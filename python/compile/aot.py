"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with `return_tuple=True`,
so the Rust side unwraps a 1-tuple. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of artifact names"
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    for name, (fn, example_args) in model.exports().items():
        if only and name not in only:
            continue
        text = to_hlo_text(fn, example_args)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
