"""L2 graph tests: the fused kmeans_step against its unfused composition,
plus export-table/shape-contract checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import SHAPES, ref

jax.config.update("jax_platform_name", "cpu")

P, C, D = SHAPES["KM_POINTS"], SHAPES["KM_CENTROIDS"], SHAPES["KM_DIMS"]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kmeans_step_equals_unfused(seed):
    r = np.random.default_rng(seed)
    pts = r.uniform(-50, 50, (P, D)).astype(np.float32)
    cents = np.full((C, D), 1e30, np.float32)
    cents[:10] = r.uniform(-50, 50, (10, D)).astype(np.float32)

    sums, counts = model.kmeans_step(pts, cents)
    assign = np.asarray(ref.kmeans_assign(pts, cents)).astype(int)
    want_counts = np.bincount(assign, minlength=C).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(counts), want_counts)
    want_sums = np.zeros((C, D), np.float32)
    np.add.at(want_sums, assign, pts)
    np.testing.assert_allclose(np.asarray(sums), want_sums, rtol=1e-4, atol=1e-2)


def test_exports_cover_rust_kernel_names():
    names = set(model.exports().keys())
    # The Rust runtime loads exactly these five; kmeans_step is extra.
    assert {"matmul", "histogram", "kmeans", "linreg", "pca"} <= names


def test_exports_are_lowerable():
    # Every export must trace and lower (the cheap 90% of `make artifacts`).
    for name, (fn, args) in model.exports().items():
        lowered = jax.jit(fn).lower(*args)
        assert lowered.compiler_ir("stablehlo") is not None, name
