"""Kernel-vs-oracle correctness: every Pallas kernel against its pure-jnp
reference, with hypothesis sweeping data distributions and paddings.

Shapes are fixed by the AOT contract (SHAPES); what varies is the data —
magnitudes, signs, padding fractions, degenerate fills — which is where
kernel bugs (wrong axis, padding leak, accumulator dtype) actually live.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import SHAPES, histogram, kmeans, linreg, matmul, pca, ref

jax.config.update("jax_platform_name", "cpu")

T = SHAPES["MM_TILE"]
HG_CHUNK, HG_BINS = SHAPES["HG_CHUNK"], SHAPES["HG_BINS"]
P, C, D = SHAPES["KM_POINTS"], SHAPES["KM_CENTROIDS"], SHAPES["KM_DIMS"]
LR_CHUNK = SHAPES["LR_CHUNK"]
PC_BLOCK = SHAPES["PC_BLOCK"]

HYP = dict(max_examples=12, deadline=None)


def rng_array(seed, shape, lo, hi, dtype=np.float32):
    r = np.random.default_rng(seed)
    return r.uniform(lo, hi, size=shape).astype(dtype)


# ---------------------------------------------------------------- matmul

@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.1, 1.0, 8.0]))
def test_matmul_matches_ref(seed, scale):
    a = rng_array(seed, (T, T), -scale, scale)
    b = rng_array(seed + 1, (T, T), -scale, scale)
    got = matmul.matmul_tile(a, b)
    want = ref.matmul_tile(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * scale * scale)


def test_matmul_zero_and_identity():
    z = np.zeros((T, T), np.float32)
    eye = np.eye(T, dtype=np.float32)
    a = rng_array(7, (T, T), -3, 3)
    np.testing.assert_array_equal(matmul.matmul_tile(a, z), z)
    np.testing.assert_allclose(matmul.matmul_tile(a, eye), a, rtol=1e-6)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_grid_matches_ref(seed):
    from compile.kernels import matmul_grid
    n = matmul_grid.N
    a = rng_array(seed, (n, n), -2, 2)
    b = rng_array(seed + 1, (n, n), -2, 2)
    got = matmul_grid.matmul_grid(a, b)
    want = ref.matmul_tile(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_matmul_grid_blocked_equals_single_tiles():
    # The grid schedule must equal composing the single-tile kernel over
    # the same block decomposition (L1-internal consistency).
    from compile.kernels import matmul_grid
    n, t = matmul_grid.N, T
    a = rng_array(3, (n, n), -1, 1)
    b = rng_array(4, (n, n), -1, 1)
    got = np.asarray(matmul_grid.matmul_grid(a, b))
    want = np.zeros((n, n), np.float32)
    for i in range(n // t):
        for j in range(n // t):
            acc = np.zeros((t, t), np.float32)
            for k in range(n // t):
                ta = a[i*t:(i+1)*t, k*t:(k+1)*t]
                tb = b[k*t:(k+1)*t, j*t:(j+1)*t]
                acc += np.asarray(matmul.matmul_tile(ta, tb))
            want[i*t:(i+1)*t, j*t:(j+1)*t] = acc
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


# ------------------------------------------------------------- histogram

@settings(**HYP)
@given(
    seed=st.integers(0, 2**31 - 1),
    pad_frac=st.sampled_from([0.0, 0.25, 0.9]),
)
def test_histogram_matches_ref(seed, pad_frac):
    r = np.random.default_rng(seed)
    vals = r.integers(0, HG_BINS, HG_CHUNK).astype(np.float32)
    n_pad = int(HG_CHUNK * pad_frac)
    if n_pad:
        vals[-n_pad:] = 512.0  # padding convention
    got = histogram.histogram_chunk(vals)
    want = ref.histogram_chunk(vals, HG_BINS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert float(np.asarray(got).sum()) == HG_CHUNK - n_pad


def test_histogram_single_bin():
    vals = np.full((HG_CHUNK,), 37.0, np.float32)
    got = np.asarray(histogram.histogram_chunk(vals))
    assert got[37] == HG_CHUNK
    assert got.sum() == HG_CHUNK


# ---------------------------------------------------------------- kmeans

@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1), live=st.sampled_from([2, 17, 100, C]))
def test_kmeans_matches_ref(seed, live):
    r = np.random.default_rng(seed)
    pts = r.uniform(-100, 100, (P, D)).astype(np.float32)
    cents = np.full((C, D), 1e30, np.float32)
    cents[:live] = r.uniform(-100, 100, (live, D)).astype(np.float32)
    got = np.asarray(kmeans.kmeans_assign(pts, cents))
    want = np.asarray(ref.kmeans_assign(pts, cents))
    # Ties can fall either way between the two formulations; require the
    # chosen centroid's distance to match the optimum instead of indices.
    d_got = ((pts - cents[got.astype(int)]) ** 2).sum(1)
    d_want = ((pts - cents[want.astype(int)]) ** 2).sum(1)
    np.testing.assert_allclose(d_got, d_want, rtol=1e-3, atol=1e-2)
    assert (got < live).all(), "padded centroid slots must never win"


def test_kmeans_exact_on_separated_clusters():
    cents = np.full((C, D), 1e30, np.float32)
    cents[0] = [0, 0, 0]
    cents[1] = [50, 0, 0]
    pts = np.zeros((P, D), np.float32)
    pts[: P // 2] += [1, 1, 1]
    pts[P // 2 :] += [49, 0, 0]
    got = np.asarray(kmeans.kmeans_assign(pts, cents))
    assert (got[: P // 2] == 0).all()
    assert (got[P // 2 :] == 1).all()


# ---------------------------------------------------------------- linreg

@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1), pad_frac=st.sampled_from([0.0, 0.5]))
def test_linreg_matches_ref(seed, pad_frac):
    xy = rng_array(seed, (LR_CHUNK, 2), -10, 10)
    n_pad = int(LR_CHUNK * pad_frac)
    if n_pad:
        xy[-n_pad:] = 0.0
    got = np.asarray(linreg.linreg_moments(xy))
    want = np.asarray(ref.linreg_moments(xy))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


# ------------------------------------------------------------------- pca

@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1))
def test_pca_matches_ref(seed):
    rows = rng_array(seed, (2, PC_BLOCK), -5, 5)
    got = np.asarray(pca.pca_pair(rows))
    want = np.asarray(ref.pca_pair(rows))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_pca_zero_padding_is_neutral():
    rows = np.zeros((2, PC_BLOCK), np.float32)
    rows[0, 0], rows[1, 0] = 3.0, 4.0
    got = np.asarray(pca.pca_pair(rows))
    np.testing.assert_array_equal(got, [3.0, 4.0, 12.0])
