//! The intermediate (key, value) collector — "the thread-safe hash table"
//! at the center of MR4J's design (§2.4), in its two forms:
//!
//! * [`ListCollector`] — the original execution flow: "a new key would
//!   instantiate a new list to collect values". Every emit appends a boxed
//!   value to the key's list; the whole population stays live until the
//!   reduce phase consumes it — the allocation behaviour behind Figure 8.
//! * [`HolderCollector`] — the optimized flow: "a new key will instantiate
//!   a new holder and the value will be combined with the intermediate
//!   value held". One holder per key; emits mutate in place — Figure 9.
//!
//! Both are sharded by key hash: emit locks only the shard owning the key,
//! so the map phase scales while preserving the shared-table semantics the
//! paper describes (as opposed to Phoenix's per-thread tables merged
//! later — that design lives in [`crate::baselines::phoenix`]).

use std::hash::Hash;
use std::sync::Mutex;

use crate::api::traits::HeapSized;
use crate::memsim::{CohortId, ThreadAlloc};
use crate::optimizer::combiner::{Combiner, Holder};
use crate::optimizer::value::Val;
use crate::util::hash::{fxhash, FxHashMap};

/// Simulated per-element overhead beyond the boxed payload: the
/// `ArrayList` slot, the amortized growth garbage of the backing array,
/// and object alignment. Calibrated against the paper's Figure 8, whose
/// measured WC heap churn is ~10 GB for ~70M intermediate values
/// (≈140 B/value total; our 16 B payload + 32 B overhead is conservative).
pub const LIST_SLOT_BYTES: u64 = 32;

/// Memsim cohorts the collectors charge allocations to.
#[derive(Clone, Copy, Debug)]
pub struct CollectorCohorts {
    /// Key objects interned into the table.
    pub keys: CohortId,
    /// Boxed intermediate values + list slots (reduce flow).
    pub intermediate: CohortId,
    /// Per-key holders (combining flow).
    pub holders: CohortId,
}

/// Pick a shard count: enough shards that `threads` workers rarely collide
/// (power of two for mask indexing).
pub fn shard_count(threads: usize) -> usize {
    (threads * 16).next_power_of_two().max(16)
}

#[inline]
pub(crate) fn shard_of(hash: u64, n_shards: usize) -> usize {
    // High bits: FxHash's low bits are weaker.
    (hash >> 48) as usize & (n_shards - 1)
}

// ---------------------------------------------------------------------
// Reduce-flow collector: key → Vec<V>
// ---------------------------------------------------------------------

/// Sharded key → value-list table.
pub struct ListCollector<K, V> {
    shards: Vec<Mutex<FxHashMap<K, Vec<V>>>>,
}

impl<K: Hash + Eq + HeapSized, V: HeapSized> ListCollector<K, V> {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.next_power_of_two().max(1);
        ListCollector {
            shards: (0..n).map(|_| Mutex::new(FxHashMap::default())).collect(),
        }
    }

    /// Append `v` to `k`'s list, charging the allocation to the memsim
    /// cohorts (one boxed value + list slot per emit; key bytes on first
    /// sight — the exact lifetime pattern the paper's Figure 8 explains).
    pub fn emit(&self, k: K, v: V, alloc: &mut ThreadAlloc, cohorts: &CollectorCohorts) {
        let value_bytes = v.heap_bytes() + LIST_SLOT_BYTES;
        let shard = shard_of(fxhash(&k), self.shards.len());
        let mut map = self.shards[shard].lock().unwrap();
        // Single-probe entry API: one hash + one lookup per emit (§Perf).
        match map.entry(k) {
            std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().push(v),
            std::collections::hash_map::Entry::Vacant(e) => {
                alloc.alloc(cohorts.keys, e.key().heap_bytes() + 48); // key + entry
                e.insert(vec![v]);
            }
        }
        drop(map);
        alloc.alloc(cohorts.intermediate, value_bytes);
    }

    /// Number of distinct keys.
    pub fn key_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Total collected values.
    pub fn value_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().values().map(Vec::len).sum::<usize>())
            .sum()
    }

    /// Take the shard maps out for the (parallel, per-shard) reduce phase.
    pub fn into_shards(self) -> Vec<FxHashMap<K, Vec<V>>> {
        self.shards
            .into_iter()
            .map(|s| s.into_inner().unwrap())
            .collect()
    }
}

// ---------------------------------------------------------------------
// Combine-flow collector: key → Holder
// ---------------------------------------------------------------------

/// Sharded key → holder table driven by a generated [`Combiner`].
pub struct HolderCollector<K> {
    shards: Vec<Mutex<FxHashMap<K, Holder>>>,
    combiner: Combiner,
}

impl<K: Hash + Eq + HeapSized> HolderCollector<K> {
    pub fn new(n_shards: usize, combiner: Combiner) -> Self {
        let n = n_shards.next_power_of_two().max(1);
        HolderCollector {
            shards: (0..n).map(|_| Mutex::new(FxHashMap::default())).collect(),
            combiner,
        }
    }

    pub fn combiner(&self) -> &Combiner {
        &self.combiner
    }

    /// Combine `v` into `k`'s holder (creating it on first sight — the only
    /// allocation this flow performs per key).
    pub fn emit(&self, k: K, v: Val, alloc: &mut ThreadAlloc, cohorts: &CollectorCohorts) {
        let shard = shard_of(fxhash(&k), self.shards.len());
        let mut map = self.shards[shard].lock().unwrap();
        match map.entry(k) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                self.combiner
                    .combine(e.get_mut(), &v)
                    .expect("verified combiner on well-typed values");
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                let mut holder = self.combiner.initialize();
                self.combiner
                    .combine(&mut holder, &v)
                    .expect("verified combiner on well-typed values");
                alloc.alloc(cohorts.keys, e.key().heap_bytes() + 48);
                alloc.alloc(cohorts.holders, holder.heap_bytes());
                e.insert(holder);
            }
        }
    }

    pub fn key_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Take the shard maps out for the (parallel) finalization phase.
    pub fn into_shards(self) -> (Vec<FxHashMap<K, Holder>>, Combiner) {
        (
            self.shards
                .into_iter()
                .map(|s| s.into_inner().unwrap())
                .collect(),
            self.combiner,
        )
    }
}

// ---------------------------------------------------------------------
// Declared-combining collector: key → typed holder
// ---------------------------------------------------------------------

/// Sharded key → *typed* holder table backing the declared combining flow
/// of the keyed dataset algebra ([`crate::api::keyed`]).
///
/// The [`HolderCollector`] works over [`Val`]-domain holders generated
/// from a reducer's RIR; this is its statically-typed twin for
/// aggregators whose holder triple is declared at the API layer — the
/// holder is the user's unboxed `H`, and combining is a direct call, no
/// IR lifting. Allocation behaviour is identical: one key object + one
/// holder per distinct key, emits mutate in place.
pub struct AggregateCollector<K, H> {
    shards: Vec<Mutex<FxHashMap<K, H>>>,
}

impl<K: Hash + Eq + HeapSized, H: HeapSized> AggregateCollector<K, H> {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.next_power_of_two().max(1);
        AggregateCollector {
            shards: (0..n).map(|_| Mutex::new(FxHashMap::default())).collect(),
        }
    }

    /// Combine `v` into `k`'s holder, creating it via `init` on first
    /// sight. The fold closures come from the stage's
    /// [`crate::api::keyed::Aggregator`]; the collector stays agnostic of
    /// that trait.
    ///
    /// Unlike [`HolderCollector`]'s fixed-size `Val`-domain holders, a
    /// declared holder may legitimately grow as it folds (a top-k list, a
    /// distinct set), so each fold charges the holder's size *delta* —
    /// the finish phase frees the final footprint and the books balance.
    pub fn combine<V>(
        &self,
        k: K,
        v: V,
        init: impl FnOnce() -> H,
        fold: impl FnOnce(&mut H, V),
        alloc: &mut ThreadAlloc,
        cohorts: &CollectorCohorts,
    ) {
        let shard = shard_of(fxhash(&k), self.shards.len());
        self.combine_at(shard, k, v, init, fold, alloc, cohorts);
    }

    /// [`AggregateCollector::combine`] with the shard chosen by the
    /// caller instead of by key hash — the hot-key split path
    /// ([`crate::stats`]): the map phase spreads a dominant key's emits
    /// round-robin across shards to break the single-shard lock convoy,
    /// and the reduce phase re-merges that key's partial holders after
    /// the barrier. Allocation accounting is identical to `combine`.
    #[allow(clippy::too_many_arguments)]
    pub fn combine_at<V>(
        &self,
        shard: usize,
        k: K,
        v: V,
        init: impl FnOnce() -> H,
        fold: impl FnOnce(&mut H, V),
        alloc: &mut ThreadAlloc,
        cohorts: &CollectorCohorts,
    ) {
        let mut map = self.shards[shard & (self.shards.len() - 1)].lock().unwrap();
        match map.entry(k) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let holder = e.get_mut();
                let before = holder.heap_bytes();
                fold(holder, v);
                let after = holder.heap_bytes();
                if after > before {
                    alloc.alloc(cohorts.holders, after - before);
                } else if before > after {
                    alloc.free(cohorts.holders, before - after);
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                let mut holder = init();
                fold(&mut holder, v);
                alloc.alloc(cohorts.keys, e.key().heap_bytes() + 48);
                alloc.alloc(cohorts.holders, holder.heap_bytes());
                e.insert(holder);
            }
        }
    }

    pub fn key_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Take the shard maps out for the (parallel) finish phase.
    pub fn into_shards(self) -> Vec<FxHashMap<K, H>> {
        self.shards
            .into_iter()
            .map(|s| s.into_inner().unwrap())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::SimHeap;
    use crate::optimizer::{agent::OptimizerAgent, builder::canon};

    fn cohorts(heap: &std::sync::Arc<SimHeap>) -> CollectorCohorts {
        CollectorCohorts {
            keys: heap.cohort("keys"),
            intermediate: heap.cohort("intermediate"),
            holders: heap.cohort("holders"),
        }
    }

    #[test]
    fn list_collector_groups_by_key() {
        let heap = SimHeap::disabled();
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        let col: ListCollector<String, i64> = ListCollector::new(8);
        for i in 0..100i64 {
            col.emit(format!("k{}", i % 10), i, &mut a, &c);
        }
        assert_eq!(col.key_count(), 10);
        assert_eq!(col.value_count(), 100);
        let shards = col.into_shards();
        let total: i64 = shards
            .iter()
            .flat_map(|m| m.values())
            .flat_map(|v| v.iter())
            .sum();
        assert_eq!(total, (0..100).sum::<i64>());
    }

    #[test]
    fn list_collector_accounts_per_value() {
        let heap = SimHeap::new(crate::memsim::HeapParams::no_injection());
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        let col: ListCollector<i64, i64> = ListCollector::new(8);
        for i in 0..1000i64 {
            col.emit(i % 4, 1, &mut a, &c);
        }
        a.flush();
        // 1000 values × (16 + slot) + 4 keys.
        let s = heap.stats();
        assert!(s.allocated_objects >= 1000);
        assert!(s.allocated_bytes >= 1000 * (16 + LIST_SLOT_BYTES));
    }

    #[test]
    fn holder_collector_combines_incrementally() {
        let heap = SimHeap::disabled();
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        let agent = OptimizerAgent::new();
        let combiner = agent
            .process(&canon::sum_i64("s"))
            .combiner()
            .cloned()
            .unwrap();
        let col: HolderCollector<String> = HolderCollector::new(8, combiner);
        for i in 0..100i64 {
            col.emit(format!("k{}", i % 5), Val::I64(i), &mut a, &c);
        }
        assert_eq!(col.key_count(), 5);
        let (shards, combiner) = col.into_shards();
        let mut total = 0i64;
        for m in shards {
            for (k, h) in m {
                let v = combiner.finalize(h, &Val::Str(k)).unwrap();
                total += v.as_i64().unwrap();
            }
        }
        assert_eq!(total, (0..100).sum::<i64>());
    }

    #[test]
    fn holder_collector_allocates_per_key_not_per_value() {
        let heap = SimHeap::new(crate::memsim::HeapParams::no_injection());
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        let agent = OptimizerAgent::new();
        let combiner = agent
            .process(&canon::sum_i64("s"))
            .combiner()
            .cloned()
            .unwrap();
        let col: HolderCollector<i64> = HolderCollector::new(8, combiner);
        for i in 0..10_000i64 {
            col.emit(i % 8, Val::I64(1), &mut a, &c);
        }
        a.flush();
        let s = heap.stats();
        // 8 keys → 16 allocations (key + holder), not 10 000.
        assert!(
            s.allocated_objects <= 32,
            "combining flow must allocate per key: {} objects",
            s.allocated_objects
        );
    }

    #[test]
    fn aggregate_collector_folds_typed_holders_per_key() {
        let heap = SimHeap::new(crate::memsim::HeapParams::no_injection());
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        let col: AggregateCollector<i64, i64> = AggregateCollector::new(8);
        for i in 0..10_000i64 {
            col.combine(i % 8, 1i64, || 0i64, |h, v| *h += v, &mut a, &c);
        }
        a.flush();
        assert_eq!(col.key_count(), 8);
        let total: i64 = col
            .into_shards()
            .into_iter()
            .flat_map(|m| m.into_values())
            .sum();
        assert_eq!(total, 10_000);
        // 8 keys → 16 allocations (key + holder), not 10 000: the
        // declared flow matches the inferred flow's allocation profile.
        let s = heap.stats();
        assert!(
            s.allocated_objects <= 32,
            "declared combining must allocate per key: {} objects",
            s.allocated_objects
        );
    }

    #[test]
    fn combine_at_routes_to_explicit_shards_preserving_totals() {
        let heap = SimHeap::disabled();
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        let col: AggregateCollector<i64, i64> = AggregateCollector::new(8);
        // Round-robin one hot key across every shard (the split path);
        // partial holders appear per shard, totals are preserved.
        for i in 0..64usize {
            col.combine_at(i, 7, 1i64, || 0i64, |h, v| *h += v, &mut a, &c);
        }
        assert_eq!(col.key_count(), 8, "one partial holder per shard");
        let total: i64 = col
            .into_shards()
            .into_iter()
            .flat_map(|m| m.into_values())
            .sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn aggregate_collector_charges_holder_growth() {
        let heap = SimHeap::new(crate::memsim::HeapParams::no_injection());
        let c = cohorts(&heap);
        let mut a = heap.thread_alloc();
        // A growable holder (top-k-style list): every fold appends.
        let col: AggregateCollector<i64, Vec<i64>> = AggregateCollector::new(8);
        for i in 0..100i64 {
            col.combine(0, i, Vec::new, |h, v| h.push(v), &mut a, &c);
        }
        a.flush();
        let s = heap.stats();
        // The final holder is 24 + 100×16 bytes; charging only the
        // first-emit footprint would book ~40 bytes and unbalance the
        // finish-phase free.
        assert!(
            s.allocated_bytes >= 24 + 100 * 16,
            "holder growth must be charged: {} bytes",
            s.allocated_bytes
        );
    }

    #[test]
    fn concurrent_emits_preserve_every_value() {
        use std::sync::Arc;
        let heap = SimHeap::disabled();
        let c = cohorts(&heap);
        let col: Arc<ListCollector<u64, i64>> = Arc::new(ListCollector::new(32));
        let threads = 8;
        let per = 5_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let col = Arc::clone(&col);
                let heap = Arc::clone(&heap);
                let c = c;
                s.spawn(move || {
                    let mut a = heap.thread_alloc();
                    for i in 0..per {
                        col.emit((t * per + i) % 97, 1, &mut a, &c);
                    }
                });
            }
        });
        assert_eq!(col.value_count() as u64, threads * per);
        assert_eq!(col.key_count(), 97);
    }

    #[test]
    fn shard_count_is_pow2_and_scales() {
        assert!(shard_count(1) >= 16);
        assert!(shard_count(8).is_power_of_two());
        assert!(shard_count(64) >= 64);
    }
}
