//! Input splitting: carve `0..n` into near-equal contiguous chunks, one
//! per map task (paper §2.1: "the input is split and individually passed
//! as an argument to the map method").

use std::ops::Range;

/// Split `0..n` into at most `parts` contiguous ranges whose lengths differ
/// by at most one. Returns fewer ranges when `n < parts`; never returns an
/// empty range.
pub fn split_indices(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split a slice by chunk *size* rather than count (Phoenix-style fixed
/// chunking, where the chunk size is derived from the L1 cache size).
pub fn split_by_chunk(n: usize, chunk: usize) -> Vec<Range<usize>> {
    if n == 0 || chunk == 0 {
        return Vec::new();
    }
    (0..n.div_ceil(chunk))
        .map(|i| i * chunk..((i + 1) * chunk).min(n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn covers(ranges: &[Range<usize>], n: usize) {
        let mut next = 0;
        for r in ranges {
            assert_eq!(r.start, next, "ranges must be contiguous");
            assert!(r.end > r.start, "no empty ranges");
            next = r.end;
        }
        assert_eq!(next, n, "ranges must cover 0..n");
    }

    #[test]
    fn exact_division() {
        let r = split_indices(100, 4);
        assert_eq!(r.len(), 4);
        covers(&r, 100);
        assert!(r.iter().all(|r| r.len() == 25));
    }

    #[test]
    fn remainder_spread() {
        let r = split_indices(10, 3);
        covers(&r, 10);
        let lens: Vec<usize> = r.iter().map(|r| r.len()).collect();
        assert_eq!(lens, vec![4, 3, 3]);
    }

    #[test]
    fn fewer_items_than_parts() {
        let r = split_indices(3, 8);
        assert_eq!(r.len(), 3);
        covers(&r, 3);
    }

    #[test]
    fn degenerate_cases() {
        assert!(split_indices(0, 4).is_empty());
        assert!(split_indices(4, 0).is_empty());
    }

    #[test]
    fn chunked_split() {
        let r = split_by_chunk(10, 4);
        assert_eq!(r, vec![0..4, 4..8, 8..10]);
        assert!(split_by_chunk(0, 4).is_empty());
        assert!(split_by_chunk(5, 0).is_empty());
    }

    #[test]
    fn property_all_splits_cover() {
        use crate::testkit::prop::{assert_prop, usize_in, Gen};
        let gen: Gen<(usize, usize)> = Gen::new(|r, _| (r.range(0, 5000), r.range(1, 64)));
        let _ = usize_in(0, 0); // keep import used in older rustc lints
        assert_prop("split covers", &gen, |&(n, parts)| {
            let ranges = split_indices(n, parts);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            if total != n {
                return Err(format!("covered {total} of {n}"));
            }
            if ranges.len() > parts.max(1) {
                return Err("too many parts".into());
            }
            let (min, max) = ranges.iter().fold((usize::MAX, 0), |(lo, hi), r| {
                (lo.min(r.len()), hi.max(r.len()))
            });
            if !ranges.is_empty() && max - min > 1 {
                return Err(format!("imbalance: min {min} max {max}"));
            }
            Ok(())
        });
    }
}
