//! The phase driver: map → barrier → (reduce | finalize) → results.
//!
//! This is where the two execution flows of the paper materialize:
//!
//! * **Reduce flow** (original): map tasks emit into a [`ListCollector`];
//!   after the barrier, reduce tasks interpret the user's reducer over each
//!   key's value list. Intermediate values live from emit until their key
//!   is reduced — the whole map phase at minimum — which is what promotes
//!   them into the old generation in the memsim.
//! * **Combine flow** (optimized): map tasks emit into a
//!   [`HolderCollector`] that applies the generated combiner at emit time;
//!   after the barrier, finalize tasks convert holders into results. The
//!   reduce phase is *gone* — paper §3's headline transformation.
//!
//! Jobs execute on a caller-supplied persistent [`WorkerPool`] (the
//! session pool a [`crate::api::Runtime`] owns), and consume their input
//! through a [`Feed`] — either a random-access slice split by index
//! ranges, or a pull-based chunk stream that is never fully materialized.
//! Result pairs are collected per shard and concatenated in shard index
//! order, so output ordering does not depend on which reduce task finished
//! first.
//!
//! Since the multi-tenant scheduler redesign, every job opens one tagged
//! [`Batch`] on the pool and submits both of its phases through it, so
//! concurrent jobs from different driver threads interleave fairly on the
//! shared workers instead of serializing. Each job also charges
//! **job-private** heap cohorts ([`crate::memsim::SimHeap::scoped_cohort`])
//! rather than name-deduplicated session cohorts, so one job's
//! end-of-job cohort release can never clobber a concurrently running
//! job's live accounting, and [`FlowMetrics::gc`] reports allocation
//! counts attributed exactly to this job even when tenants share a heap.

use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::collector::{
    shard_count, AggregateCollector, CollectorCohorts, HolderCollector, ListCollector,
};
use super::scheduler::{Batch, BatchId, PoolStats, WorkerPool};
use super::splitter::split_indices;
use crate::api::config::{ExecutionFlow, JobConfig, OptimizeMode};
use crate::api::source::Feed;
use crate::api::traits::{Emitter, HeapSized, KeyValue, Mapper, Reducer};
use crate::cache::CacheActivity;
use crate::memsim::{CohortId, GcStats, SimHeap, ThreadAlloc};
use crate::optimizer::agent::{CombinerSource, Decision, OptimizerAgent};
use crate::optimizer::value::RirValue;
use crate::stats::{KeySkew, MajorityTracker, SkewSketch, StageAdapt};
use crate::trace::SpanKind;
use crate::util::hash::fxhash;
use crate::util::timer::Stopwatch;

/// Per-job measurements (the figures are built from these).
///
/// This is the *aggregate* view — one summary per executed job. The
/// event-level view (when individual tasks ran, on which worker, and
/// what the cache/heap did in between) lives on the session
/// [`Tracer`](crate::trace::Tracer) and the
/// [`MetricsRegistry`](crate::trace::MetricsRegistry); see
/// [`crate::trace`].
#[derive(Clone, Debug)]
pub struct FlowMetrics {
    /// Which flow ran.
    pub flow: ExecutionFlow,
    /// Which semantic channel supplied the combiner when the combine flow
    /// ran: [`CombinerSource::Inferred`] for RIR-analyzed reducers,
    /// [`CombinerSource::Declared`] for keyed [`crate::api::keyed::Aggregator`]
    /// stages. `None` when no combining rewrite fired.
    pub combiner_source: Option<CombinerSource>,
    /// Why the combine flow was not taken (when it wasn't).
    pub fallback_reason: Option<String>,
    /// Intermediate values shipped *individually* across the map→reduce
    /// barrier (the reduce flow ships every surviving emit).
    pub shuffled_pairs: u64,
    /// Per-key holders shipped across the barrier instead (the combining
    /// flows collapse the pair stream in the map phase).
    pub shuffled_holders: u64,
    /// Payload heap bytes crossing the barrier — boxed values + list
    /// slots for the reduce flow, holder footprints for combining flows.
    /// The declared-vs-materialized comparison the keyed acceptance
    /// criteria measure.
    pub shuffled_bytes: u64,
    /// Input elements that were materialized into a plan-level
    /// intermediate buffer before this stage's map phase (the `JobOutput`
    /// round-trip of the eager API). Zero for borrowed sources, streamed
    /// shard handoffs, and fused element-wise chains; set by the plan
    /// executor ([`crate::coordinator::planner`]).
    pub materialized_in: u64,
    pub map_secs: f64,
    /// Reduce (or finalize) phase time.
    pub reduce_secs: f64,
    pub total_secs: f64,
    /// Map-phase emits.
    pub emits: u64,
    /// Distinct intermediate keys.
    pub keys: u64,
    /// Result pairs produced.
    pub results: u64,
    /// GC activity during this job. Collection/pause counters are the
    /// delta of the (possibly shared) heap's stats over the job;
    /// `allocated_bytes`/`allocated_objects` are attributed exactly to
    /// this job via its private cohorts, so they stay correct when
    /// concurrent jobs share one session heap.
    pub gc: GcStats,
    /// Map-phase scheduling stats.
    pub map_pool: PoolStats,
    /// The pool batch this job's phases ran under — the per-tenant
    /// scheduling tag ([`crate::coordinator::scheduler::Batch`]).
    pub batch: BatchId,
    /// Cumulative scheduling stats of this job's batch across both phases
    /// (map + reduce/finalize). Per-batch values sum to
    /// [`WorkerPool::totals`] between quiescent points.
    pub batch_pool: PoolStats,
    /// Materialization-cache activity involved in resolving this stage's
    /// *input* (set by the plan executor on the stage downstream of a
    /// [`Dataset::cache`](crate::api::plan::Dataset::cache) cut point:
    /// a hit means the stage's input was read back instead of recomputed;
    /// a reload means it was promoted back from the cold spill tier at
    /// simulated `reload_bytes` of heap traffic — see
    /// [`crate::cache::tier`]). `None` for stages with no cut point
    /// upstream.
    pub cache: Option<CacheActivity>,
    /// Key-frequency sketch of this stage's emit stream (Boyer–Moore
    /// majority candidate + surplus), collected when the stage observes
    /// for the adaptive feedback store ([`crate::stats`]). Only keyed
    /// stages whose aggregator is `MERGEABLE` observe — the precondition
    /// for acting on the sketch with a hot-key split.
    pub skew: Option<KeySkew>,
}

/// Standing-query measurements — the streaming counterpart of
/// [`FlowMetrics`], reported as
/// [`PlanReport::stream`](crate::api::plan::PlanReport) by
/// [`crate::stream`] queries and windowed batch collects. Counters are
/// cumulative over the query's lifetime.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StreamMetrics {
    /// Chunks the source delivered.
    pub chunks_ingested: u64,
    /// Elements across all ingested chunks.
    pub elements_ingested: u64,
    /// Windows fired (closed and emitted).
    pub windows_fired: u64,
    /// Panes retired after their last consuming window fired (each
    /// retirement frees the pane's buffered bytes in the memsim).
    pub panes_fired: u64,
    /// Pane holders absorbed into window accumulators via
    /// [`Aggregator::merge_holders`](crate::api::keyed::Aggregator) — the
    /// mergeable path's unit of work: each pane's per-key holder is
    /// folded exactly once per consuming window, never rebuilt from raw
    /// values.
    pub holders_merged: u64,
    /// Holders rebuilt from scratch at window close on the buffered
    /// fallback path (non-mergeable aggregator or optimizer off).
    pub holders_recomputed: u64,
    /// Raw values re-folded at window close on the buffered fallback
    /// path. Zero on the mergeable path — the headline saving.
    pub elements_recomputed: u64,
    /// Elements whose pane had already been retired when they arrived
    /// (dropped; their windows fired without them).
    pub late_elements: u64,
    /// Event-time distance between the watermark (max timestamp seen)
    /// and the end of the last fired window — how far emission trails
    /// ingestion.
    pub watermark_lag: u64,
    /// Whether the holder-merge path was granted (see `fallback_reason`
    /// otherwise).
    pub merge_mode: bool,
    /// Why the merge path was refused, when it was (`"optimizer off"`,
    /// a missing declared marker, or a non-mergeable holder).
    pub fallback_reason: Option<String>,
    /// Producer pushes that blocked on a bounded source's full queue
    /// ([`StreamSource::bounded`](crate::stream::StreamSource::bounded))
    /// — the backpressure observable.
    pub pushes_blocked: u64,
    /// Producer `try_push` chunks handed back at a full queue.
    pub pushes_shed: u64,
}

/// The memsim cohorts a job charges, released on drop — on success *and*
/// unwind: a panicking tenant must not leak its scoped cohort slots (or
/// their live bytes) on a shared session heap, or every surviving
/// tenant's GC accounting would degrade with each panic.
struct JobCohorts {
    heap: Arc<SimHeap>,
    collector: CollectorCohorts,
    scratch: CohortId,
    results: CohortId,
}

/// Register this job's **private** cohorts. Scoped (not name-deduplicated)
/// registration is what makes shared-session GC accounting safe under
/// concurrent jobs: two tenants both running word counts get disjoint
/// cohort ids, so the end-of-job release only kills *this* job's bytes
/// and per-job allocation attribution stays exact.
fn job_cohorts(cfg: &JobConfig) -> JobCohorts {
    JobCohorts {
        heap: Arc::clone(&cfg.heap),
        collector: CollectorCohorts {
            keys: cfg.heap.scoped_cohort("mr4r.keys"),
            intermediate: cfg.heap.scoped_cohort("mr4r.intermediate"),
            holders: cfg.heap.scoped_cohort("mr4r.holders"),
        },
        scratch: cfg.heap.scoped_cohort("mr4r.scratch"),
        results: cfg.heap.scoped_cohort("mr4r.results"),
    }
}

impl JobCohorts {
    fn ids(&self) -> [CohortId; 5] {
        [
            self.collector.keys,
            self.collector.intermediate,
            self.collector.holders,
            self.scratch,
            self.results,
        ]
    }

    /// Sum this job's own allocation counters (its per-plan GC delta —
    /// exact even when concurrent jobs share the session heap, unlike
    /// the heap-global counters).
    fn allocated(&self) -> (u64, u64) {
        let mut bytes = 0u64;
        let mut objects = 0u64;
        for id in self.ids() {
            let (b, o) = self.heap.cohort_allocated(id);
            bytes += b;
            objects += o;
        }
        (bytes, objects)
    }
}

impl Drop for JobCohorts {
    fn drop(&mut self) {
        for id in self.ids() {
            self.heap.release_cohort(id);
        }
    }
}

/// The end-of-job epilogue every flow shares: read the job's exact
/// allocation attribution, release its cohorts (by consuming `cohorts`),
/// credit the job's tenant (when governed) with its exact footprint —
/// the budget signal [`crate::govern`] admission reads — and assemble
/// the GC delta plus the batch tag for the flow's metrics.
fn job_epilogue(
    cfg: &JobConfig,
    cohorts: JobCohorts,
    gc_before: &GcStats,
    batch: &Batch<'_>,
) -> (GcStats, BatchId, PoolStats) {
    let (alloc_bytes, alloc_objects) = cohorts.allocated();
    if let Some(tenant) = &cfg.govern {
        tenant.note_job(alloc_bytes, alloc_objects);
    }
    drop(cohorts);
    let mut gc = cfg.heap.stats().since(gc_before);
    gc.allocated_bytes = alloc_bytes;
    gc.allocated_objects = alloc_objects;
    (gc, batch.id(), batch.stats())
}

/// Open a job's tagged batch on the pool. Governed configs (a resolved
/// tenant on [`JobConfig`]) carry the tenant's weighted-round-robin
/// quota and scheduler counters into the pool's pick loop; ungoverned
/// configs open a plain weight-1 batch — bit-for-bit the pre-governance
/// behaviour.
pub(crate) fn batch_for<'p>(pool: &'p WorkerPool, cfg: &JobConfig) -> Batch<'p> {
    match &cfg.govern {
        Some(tenant) => pool.batch_with(tenant.quota(), Some(Arc::clone(tenant.qos()))),
        None => pool.batch(),
    }
}

/// Run a complete MapReduce job on a transient pool (the legacy slice
/// entry point — [`crate::api::MapReduce`] and older call sites). New
/// code should go through [`crate::api::Runtime`], which reuses one pool
/// across jobs via [`run_job_on`].
pub fn run_job<I, K, V>(
    mapper: &dyn Mapper<I, K, V>,
    reducer: &dyn Reducer<K, V>,
    inputs: &[I],
    cfg: &JobConfig,
    agent: &OptimizerAgent,
) -> (Vec<KeyValue<K, V>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    let pool = WorkerPool::new(cfg.threads);
    run_job_on(&pool, mapper, reducer, Feed::Slice(inputs), cfg, agent)
}

/// Run a complete MapReduce job on a persistent pool, consuming any
/// [`Feed`]. The agent decides the flow; results are identical either way
/// (asserted extensively in `rust/tests/`).
pub fn run_job_on<I, K, V>(
    pool: &WorkerPool,
    mapper: &dyn Mapper<I, K, V>,
    reducer: &dyn Reducer<K, V>,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    agent: &OptimizerAgent,
) -> (Vec<KeyValue<K, V>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    let (shards, metrics) = run_job_sharded(pool, mapper, reducer, feed, cfg, agent);
    (concat_shards(shards), metrics)
}

/// [`run_job_on`], but returning result pairs **grouped by collector
/// shard** in shard index order, without concatenating them. This is the
/// handoff shape the plan executor streams into a downstream stage's
/// splitter — the concatenation (and its copy) only happens when someone
/// actually asks for one flat `Vec` (see [`concat_shards`]).
pub fn run_job_sharded<I, K, V>(
    pool: &WorkerPool,
    mapper: &dyn Mapper<I, K, V>,
    reducer: &dyn Reducer<K, V>,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    agent: &OptimizerAgent,
) -> (Vec<Vec<KeyValue<K, V>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    run_job_sharded_adaptive(pool, mapper, reducer, feed, cfg, agent, None)
}

/// [`run_job_sharded`] with per-stage adaptive hints from the session's
/// feedback store ([`crate::stats`]). For RIR stages only the observed
/// shard-count override applies (the combining rewrite itself stays on
/// the agent's per-class path, and hot-key splitting needs a declared
/// merge — see [`run_keyed_sharded_adaptive`]). `None` hints reproduce
/// the static plan bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn run_job_sharded_adaptive<I, K, V>(
    pool: &WorkerPool,
    mapper: &dyn Mapper<I, K, V>,
    reducer: &dyn Reducer<K, V>,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    agent: &OptimizerAgent,
    adapt: Option<&StageAdapt>,
) -> (Vec<Vec<KeyValue<K, V>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    let n_shards = adapt
        .and_then(|a| a.shard_override)
        .unwrap_or_else(|| shard_count(cfg.threads));
    // --- Flow decision (the "class load time" hook) -------------------
    // `effective_optimize` honours the tenant degrade latch: a governed
    // job admitted under pressure runs the reduce flow (results are
    // rewrite-independent, so this sheds speed, never correctness).
    let decision = match (cfg.effective_optimize(), reducer.rir()) {
        (OptimizeMode::Off, _) => None,
        (_, None) => {
            agent.note_opaque();
            Some(Decision::Opaque)
        }
        (mode, Some(program)) => {
            let d = agent.process(program);
            match (mode, d) {
                (OptimizeMode::GenericOnly, Decision::Combine(c)) => {
                    Some(Decision::Combine(c.without_fast_path()))
                }
                (_, d) => Some(d),
            }
        }
    };

    // One tagged batch per job: both phases submit through it, so this
    // job's scheduling is observable (and fair against concurrent jobs).
    let batch = batch_for(pool, cfg);
    match decision {
        Some(Decision::Combine(combiner)) => {
            run_combine_flow(&batch, mapper, feed, cfg, combiner, n_shards)
        }
        Some(Decision::Fallback(reason)) => run_reduce_flow(
            &batch,
            mapper,
            reducer,
            feed,
            cfg,
            Some(reason.to_string()),
            n_shards,
        ),
        Some(Decision::Opaque) => run_reduce_flow(
            &batch,
            mapper,
            reducer,
            feed,
            cfg,
            Some("opaque reducer".into()),
            n_shards,
        ),
        None => run_reduce_flow(
            &batch,
            mapper,
            reducer,
            feed,
            cfg,
            Some("optimizer off".into()),
            n_shards,
        ),
    }
}

// ---------------------------------------------------------------------
// Map-phase emitters
// ---------------------------------------------------------------------

/// Emitter backing the original flow: append to the key's value list.
struct ListEmitter<'a, K: Hash + Eq + HeapSized, V: HeapSized> {
    collector: &'a ListCollector<K, V>,
    alloc: ThreadAlloc,
    cohorts: CollectorCohorts,
    scratch: CohortId,
    scratch_per_emit: u64,
    emits: u64,
}

impl<K: Hash + Eq + HeapSized, V: HeapSized> Emitter<K, V> for ListEmitter<'_, K, V> {
    #[inline]
    fn emit(&mut self, key: K, value: V) {
        if self.scratch_per_emit > 0 {
            self.alloc.scratch(self.scratch, self.scratch_per_emit);
        }
        self.collector
            .emit(key, value, &mut self.alloc, &self.cohorts);
        self.emits += 1;
    }
}

/// Emitter backing the optimized flow: combine into the key's holder.
struct CombineEmitter<'a, K: Hash + Eq + HeapSized, V: RirValue> {
    collector: &'a HolderCollector<K>,
    alloc: ThreadAlloc,
    cohorts: CollectorCohorts,
    scratch: CohortId,
    scratch_per_emit: u64,
    emits: u64,
    _v: std::marker::PhantomData<fn(V)>,
}

impl<K: Hash + Eq + HeapSized, V: RirValue> Emitter<K, V> for CombineEmitter<'_, K, V> {
    #[inline]
    fn emit(&mut self, key: K, value: V) {
        if self.scratch_per_emit > 0 {
            self.alloc.scratch(self.scratch, self.scratch_per_emit);
        }
        self.collector
            .emit(key, value.into_val(), &mut self.alloc, &self.cohorts);
        self.emits += 1;
    }
}

/// Result emitter used by reduce tasks.
struct ResultEmitter<K, V> {
    out: Vec<KeyValue<K, V>>,
}

impl<K, V> Emitter<K, V> for ResultEmitter<K, V> {
    fn emit(&mut self, key: K, value: V) {
        self.out.push(KeyValue::new(key, value));
    }
}

// ---------------------------------------------------------------------
// Shared phase drivers
// ---------------------------------------------------------------------

/// Drive the map phase over a feed: slice feeds are pre-split into index
/// ranges (one task each, work-stealing balances the rest); stream feeds
/// run one puller task per worker, each looping "pull chunk → map chunk"
/// so un-materialized inputs stay bounded in memory. `map_chunk` maps one
/// chunk of inputs and returns its emit count. Tasks submit through the
/// job's tagged [`Batch`], never assuming exclusive pool ownership.
fn map_phase<I: Send + Sync>(
    batch: &Batch<'_>,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    map_chunk: &(dyn Fn(&[I]) -> u64 + Sync),
) -> (PoolStats, u64) {
    let obs = batch.pool().obs();
    let map_start = obs.map(|o| o.tracer.now_us());
    let emits = AtomicU64::new(0);
    let n_tasks;
    let stats = match feed {
        Feed::Slice(inputs) => {
            let chunks = split_indices(inputs.len(), cfg.threads * cfg.tasks_per_thread);
            n_tasks = chunks.len() as u64;
            batch.run(
                cfg.threads,
                chunks
                    .into_iter()
                    .map(|range| {
                        let emits = &emits;
                        move |_wid: usize| {
                            emits.fetch_add(map_chunk(&inputs[range]), Ordering::Relaxed);
                        }
                    })
                    .collect::<Vec<_>>(),
            )
        }
        Feed::Stream(puller) => {
            let puller = Mutex::new(puller);
            n_tasks = cfg.threads.max(1) as u64;
            batch.run(
                cfg.threads,
                (0..cfg.threads.max(1))
                    .map(|_| {
                        let puller = &puller;
                        let emits = &emits;
                        move |_wid: usize| loop {
                            let chunk = {
                                let mut next = puller.lock().unwrap();
                                (*next)()
                            };
                            match chunk {
                                Some(items) => {
                                    emits.fetch_add(map_chunk(&items), Ordering::Relaxed);
                                }
                                None => break,
                            }
                        }
                    })
                    .collect::<Vec<_>>(),
            )
        }
    };
    if let Some(o) = obs {
        o.tracer
            .record_since(SpanKind::MapPhase, map_start.unwrap_or(0), batch.id().0, n_tasks);
    }
    (stats, emits.load(Ordering::Relaxed))
}

/// Unwrap per-shard result slots in **shard index order** — reduce and
/// finalize tasks complete in a nondeterministic order, so each writes
/// its own indexed slot and the slot sequence is order-stable.
fn unwrap_slots<K, V>(slots: Vec<Mutex<Vec<KeyValue<K, V>>>>) -> Vec<Vec<KeyValue<K, V>>> {
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap())
        .collect()
}

/// Flatten sharded results into one vector, preserving shard index order
/// (the output ordering contract of [`run_job_on`]).
pub fn concat_shards<T>(shards: Vec<Vec<T>>) -> Vec<T> {
    let mut results = Vec::with_capacity(shards.iter().map(Vec::len).sum());
    for mut shard in shards {
        results.append(&mut shard);
    }
    results
}

// ---------------------------------------------------------------------
// The two flows
// ---------------------------------------------------------------------

fn run_reduce_flow<I, K, V>(
    batch: &Batch<'_>,
    mapper: &dyn Mapper<I, K, V>,
    reducer: &dyn Reducer<K, V>,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    fallback_reason: Option<String>,
    n_shards: usize,
) -> (Vec<Vec<KeyValue<K, V>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    let total_sw = Stopwatch::start();
    let cohorts = job_cohorts(cfg);
    let gc_before = cfg.heap.stats();
    let collector: ListCollector<K, V> = ListCollector::new(n_shards);

    // ---- Map phase ----
    let map_sw = Stopwatch::start();
    let map_chunk = |items: &[I]| -> u64 {
        let mut em = ListEmitter {
            collector: &collector,
            alloc: cfg.heap.thread_alloc(),
            cohorts: cohorts.collector,
            scratch: cohorts.scratch,
            scratch_per_emit: cfg.scratch_per_emit,
            emits: 0,
        };
        for input in items {
            mapper.map(input, &mut em);
        }
        em.alloc.flush();
        em.emits
    };
    let (map_pool, emits) = map_phase(batch, feed, cfg, &map_chunk);
    let map_secs = map_sw.secs();

    // ---- Barrier; reduce phase over shards ----
    let reduce_sw = Stopwatch::start();
    let keys = collector.key_count() as u64;
    let shards = collector.into_shards();
    let shuffled_bytes = AtomicU64::new(0);
    let slots: Vec<Mutex<Vec<KeyValue<K, V>>>> =
        (0..shards.len()).map(|_| Mutex::new(Vec::new())).collect();
    batch.run(
        cfg.threads,
        shards
            .into_iter()
            .enumerate()
            .map(|(si, shard)| {
                let slots = &slots;
                let cohorts = &cohorts;
                let shuffled_bytes = &shuffled_bytes;
                move |_wid: usize| {
                    let mut alloc = cfg.heap.thread_alloc();
                    let mut shard_bytes = 0u64;
                    let mut em = ResultEmitter { out: Vec::new() };
                    for (k, values) in shard {
                        reducer.reduce(&k, &values, &mut em);
                        // The key's list dies once reduced (paper Fig. 1:
                        // values are consumed by the reduce method).
                        let bytes: u64 = values
                            .iter()
                            .map(|v| v.heap_bytes() + super::collector::LIST_SLOT_BYTES)
                            .sum();
                        shard_bytes += bytes;
                        alloc.free(cohorts.collector.intermediate, bytes);
                    }
                    for kv in &em.out {
                        alloc.alloc(cohorts.results, kv.value.heap_bytes());
                    }
                    alloc.flush();
                    shuffled_bytes.fetch_add(shard_bytes, Ordering::Relaxed);
                    *slots[si].lock().unwrap() = em.out;
                }
            })
            .collect::<Vec<_>>(),
    );
    let reduce_secs = reduce_sw.secs();
    if let Some(o) = batch.pool().obs() {
        o.tracer
            .record_with_dur(SpanKind::ReducePhase, reduce_secs, batch.id().0, slots.len() as u64);
    }

    let results = unwrap_slots(slots);
    let (gc, batch_id, batch_pool) = job_epilogue(cfg, cohorts, &gc_before, batch);
    let metrics = FlowMetrics {
        flow: ExecutionFlow::Reduce,
        combiner_source: None,
        fallback_reason,
        shuffled_pairs: emits,
        shuffled_holders: 0,
        shuffled_bytes: shuffled_bytes.load(Ordering::Relaxed),
        materialized_in: 0,
        map_secs,
        reduce_secs,
        total_secs: total_sw.secs(),
        emits,
        keys,
        results: results.iter().map(|s| s.len() as u64).sum(),
        gc,
        map_pool,
        batch: batch_id,
        batch_pool,
        cache: None,
        skew: None,
    };
    (results, metrics)
}

fn run_combine_flow<I, K, V>(
    batch: &Batch<'_>,
    mapper: &dyn Mapper<I, K, V>,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    combiner: crate::optimizer::combiner::Combiner,
    n_shards: usize,
) -> (Vec<Vec<KeyValue<K, V>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    let total_sw = Stopwatch::start();
    let cohorts = job_cohorts(cfg);
    let gc_before = cfg.heap.stats();
    let collector: HolderCollector<K> = HolderCollector::new(n_shards, combiner);

    // ---- Map phase (combining at emit time) ----
    let map_sw = Stopwatch::start();
    let map_chunk = |items: &[I]| -> u64 {
        let mut em: CombineEmitter<'_, K, V> = CombineEmitter {
            collector: &collector,
            alloc: cfg.heap.thread_alloc(),
            cohorts: cohorts.collector,
            scratch: cohorts.scratch,
            scratch_per_emit: cfg.scratch_per_emit,
            emits: 0,
            _v: std::marker::PhantomData,
        };
        for input in items {
            mapper.map(input, &mut em);
        }
        em.alloc.flush();
        em.emits
    };
    let (map_pool, emits) = map_phase(batch, feed, cfg, &map_chunk);
    let map_secs = map_sw.secs();

    // ---- Barrier; finalize phase (no reduce phase at all) ----
    let fin_sw = Stopwatch::start();
    let keys = collector.key_count() as u64;
    let (shards, combiner) = collector.into_shards();
    let shuffled_bytes = AtomicU64::new(0);
    let slots: Vec<Mutex<Vec<KeyValue<K, V>>>> =
        (0..shards.len()).map(|_| Mutex::new(Vec::new())).collect();
    batch.run(
        cfg.threads,
        shards
            .into_iter()
            .enumerate()
            .map(|(si, shard)| {
                let slots = &slots;
                let cohorts = &cohorts;
                let combiner = &combiner;
                let shuffled_bytes = &shuffled_bytes;
                move |_wid: usize| {
                    let mut alloc = cfg.heap.thread_alloc();
                    let mut shard_bytes = 0u64;
                    let mut out = Vec::with_capacity(shard.len());
                    for (k, holder) in shard {
                        shard_bytes += holder.heap_bytes();
                        alloc.free(cohorts.collector.holders, holder.heap_bytes());
                        let key_val = k.to_val();
                        let v = combiner
                            .finalize(holder, &key_val)
                            .expect("verified combiner");
                        let v = V::from_val(v)
                            .expect("combiner produces the reducer's value type");
                        alloc.alloc(cohorts.results, v.heap_bytes());
                        out.push(KeyValue::new(k, v));
                    }
                    alloc.flush();
                    shuffled_bytes.fetch_add(shard_bytes, Ordering::Relaxed);
                    *slots[si].lock().unwrap() = out;
                }
            })
            .collect::<Vec<_>>(),
    );
    let reduce_secs = fin_sw.secs();
    if let Some(o) = batch.pool().obs() {
        o.tracer
            .record_with_dur(SpanKind::ReducePhase, reduce_secs, batch.id().0, slots.len() as u64);
    }

    let results = unwrap_slots(slots);
    let (gc, batch_id, batch_pool) = job_epilogue(cfg, cohorts, &gc_before, batch);
    let metrics = FlowMetrics {
        flow: ExecutionFlow::Combine,
        combiner_source: Some(CombinerSource::Inferred),
        fallback_reason: None,
        shuffled_pairs: 0,
        shuffled_holders: keys,
        shuffled_bytes: shuffled_bytes.load(Ordering::Relaxed),
        materialized_in: 0,
        map_secs,
        reduce_secs,
        total_secs: total_sw.secs(),
        emits,
        keys,
        results: results.iter().map(|s| s.len() as u64).sum(),
        gc,
        map_pool,
        batch: batch_id,
        batch_pool,
        cache: None,
        skew: None,
    };
    (results, metrics)
}

// ---------------------------------------------------------------------
// Keyed flows (the declared-semantics channel)
// ---------------------------------------------------------------------

/// Pair extraction the keyed flows drive — the keyed analogue of a
/// [`Mapper`]: one input element pushes any number of `(K, V)` pairs into
/// the sink (the stage's fused element-wise chain lives inside this
/// closure, exactly like [`crate::api::plan`]'s `FusedMapper`).
pub type PairFn<'a, I, K, V> = &'a (dyn Fn(&I, &mut dyn FnMut(K, V)) + Sync);

/// Adaptive context a keyed stage hands to
/// [`run_keyed_sharded_adaptive`]: the lowering-time hints for this
/// stage, whether to collect the key-frequency sketch for the feedback
/// store, and the aggregator's declared holder merge (present only for
/// `MERGEABLE` aggregators — the hot-key split's correctness
/// precondition). The default reproduces the static executor.
pub struct KeyedAdaptive<'a, H> {
    /// Hints derived from the feedback store at lowering time.
    pub adapt: Option<&'a StageAdapt>,
    /// Collect the Boyer–Moore sketch into [`FlowMetrics::skew`].
    pub observe: bool,
    /// `Aggregator::merge_holders` as a closure, for re-merging a split
    /// hot key's partial holders after the barrier.
    pub merge: Option<&'a (dyn Fn(&mut H, H) + Sync)>,
}

impl<H> Default for KeyedAdaptive<'_, H> {
    fn default() -> Self {
        KeyedAdaptive {
            adapt: None,
            observe: false,
            merge: None,
        }
    }
}

/// Run one keyed aggregation stage, sharded. The *declared* counterpart
/// of [`run_job_sharded`]: instead of consulting the agent's RIR analysis,
/// the stage hands over its [`crate::api::keyed::Aggregator`]'s holder
/// triple (as closures) plus the declared algebraic markers, and the
/// agent's declared channel ([`OptimizerAgent::process_declared`]) decides
/// whether the in-map combining flow may run:
///
/// * **Combining flow** (associative + commutative, optimizer on): every
///   worker folds pairs straight into a sharded table of *unboxed typed
///   holders* ([`AggregateCollector`]); the barrier ships one holder per
///   key instead of every emitted pair — the paper's Fig. 4 rewrite, with
///   the triple supplied by the user rather than sliced from bytecode.
/// * **List flow** (optimizer off, or a marker missing): pairs collect
///   into per-key lists ([`ListCollector`]) and the holder triple runs
///   sequentially per key after the barrier — the measured baseline.
///
/// Results are identical either way (`rust/tests/keyed_equivalence.rs`);
/// [`FlowMetrics::shuffled_pairs`]/[`FlowMetrics::shuffled_holders`]/
/// [`FlowMetrics::shuffled_bytes`] quantify the difference.
#[allow(clippy::too_many_arguments)]
pub fn run_keyed_sharded<I, K, V, H, O, FI, FC, FF>(
    pool: &WorkerPool,
    class: &str,
    associative: bool,
    commutative: bool,
    pairs: PairFn<'_, I, K, V>,
    init: FI,
    fold: FC,
    finish: FF,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    agent: &OptimizerAgent,
) -> (Vec<Vec<KeyValue<K, O>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + HeapSized,
    V: Send + HeapSized,
    H: Send + HeapSized,
    O: Send + HeapSized,
    FI: Fn() -> H + Sync,
    FC: Fn(&mut H, V) + Sync,
    FF: Fn(H) -> O + Sync,
{
    run_keyed_sharded_adaptive(
        pool,
        class,
        associative,
        commutative,
        pairs,
        init,
        fold,
        finish,
        feed,
        cfg,
        agent,
        KeyedAdaptive::default(),
    )
}

/// [`run_keyed_sharded`] with adaptive execution: lowering-time hints may
/// shrink the collector to the observed key cardinality, demote the
/// declared combining flow to the list flow (measured holder growth), or
/// split a dominant key round-robin across shards (partial holders
/// re-merged by the aggregator's declared `merge_holders` after the
/// barrier — only offered when `ctx.merge` is present). With
/// `KeyedAdaptive::default()` this *is* [`run_keyed_sharded`].
#[allow(clippy::too_many_arguments)]
pub fn run_keyed_sharded_adaptive<I, K, V, H, O, FI, FC, FF>(
    pool: &WorkerPool,
    class: &str,
    associative: bool,
    commutative: bool,
    pairs: PairFn<'_, I, K, V>,
    init: FI,
    fold: FC,
    finish: FF,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    agent: &OptimizerAgent,
    ctx: KeyedAdaptive<'_, H>,
) -> (Vec<Vec<KeyValue<K, O>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + HeapSized,
    V: Send + HeapSized,
    H: Send + HeapSized,
    O: Send + HeapSized,
    FI: Fn() -> H + Sync,
    FC: Fn(&mut H, V) + Sync,
    FF: Fn(H) -> O + Sync,
{
    let optimize = cfg.effective_optimize();
    let prefer_list = ctx.adapt.is_some_and(|a| a.prefer_list);
    let combine = match optimize {
        OptimizeMode::Off => false,
        _ => agent.process_declared(class, associative, commutative) && !prefer_list,
    };
    let n_shards = ctx
        .adapt
        .and_then(|a| a.shard_override)
        .unwrap_or_else(|| shard_count(cfg.threads));
    // One tagged batch per keyed stage, like `run_job_sharded`.
    let batch = batch_for(pool, cfg);
    if combine {
        // The split only applies where it is correct: a declared holder
        // merge must be available to reunify the hot key's partials.
        let hot_key = ctx.adapt.and_then(|a| a.hot_key).filter(|_| ctx.merge.is_some());
        run_declared_combine_flow(
            &batch,
            pairs,
            &init,
            &fold,
            &finish,
            feed,
            cfg,
            n_shards,
            ctx.observe,
            hot_key,
            ctx.merge,
        )
    } else {
        let reason = if matches!(optimize, OptimizeMode::Off) {
            "optimizer off"
        } else if !associative {
            "declared non-associative"
        } else if !commutative {
            "declared non-commutative"
        } else {
            "adaptive: measured holder growth prefers the list flow"
        };
        run_keyed_list_flow(
            &batch,
            pairs,
            &init,
            &fold,
            &finish,
            feed,
            cfg,
            reason,
            n_shards,
            ctx.observe,
        )
    }
}

/// The declared combining flow: fold pairs into typed holders at emit
/// time, ship one holder per key (mirrors [`run_combine_flow`]).
///
/// When `hot_key` is set (with its `merge` closure), emits of the
/// matching key hash are spread round-robin across all shards instead of
/// convoying on the one shard lock the hash owns; after the barrier the
/// split key's partial holders are re-merged — by key *equality*, so a
/// colliding cold key merges harmlessly into its own entry — into the
/// key's canonical shard, preserving both results and the output's
/// shard-order contract.
#[allow(clippy::too_many_arguments)]
fn run_declared_combine_flow<I, K, V, H, O>(
    batch: &Batch<'_>,
    pairs: PairFn<'_, I, K, V>,
    init: &(dyn Fn() -> H + Sync),
    fold: &(dyn Fn(&mut H, V) + Sync),
    finish: &(dyn Fn(H) -> O + Sync),
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    n_shards: usize,
    observe: bool,
    hot_key: Option<u64>,
    merge: Option<&(dyn Fn(&mut H, H) + Sync)>,
) -> (Vec<Vec<KeyValue<K, O>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + HeapSized,
    V: Send + HeapSized,
    H: Send + HeapSized,
    O: Send + HeapSized,
{
    let total_sw = Stopwatch::start();
    let cohorts = job_cohorts(cfg);
    let gc_before = cfg.heap.stats();
    let collector: AggregateCollector<K, H> = AggregateCollector::new(n_shards);
    let sketch = Mutex::new(SkewSketch::default());
    let hot_rr = AtomicU64::new(0);

    // ---- Map phase (combining at emit time) ----
    let map_sw = Stopwatch::start();
    let map_chunk = |items: &[I]| -> u64 {
        let mut alloc = cfg.heap.thread_alloc();
        let mut emits = 0u64;
        let mut tracker = MajorityTracker::new();
        for input in items {
            pairs(input, &mut |k, v| {
                if cfg.scratch_per_emit > 0 {
                    alloc.scratch(cohorts.scratch, cfg.scratch_per_emit);
                }
                let hash = fxhash(&k);
                if observe {
                    tracker.hit(hash);
                }
                let shard = match hot_key {
                    Some(hot) if hash == hot => {
                        hot_rr.fetch_add(1, Ordering::Relaxed) as usize & (n_shards - 1)
                    }
                    _ => super::collector::shard_of(hash, n_shards),
                };
                collector.combine_at(shard, k, v, init, fold, &mut alloc, &cohorts.collector);
                emits += 1;
            });
        }
        alloc.flush();
        if observe {
            let (cand, weight) = tracker.summary();
            sketch.lock().unwrap().absorb(cand, weight);
        }
        emits
    };
    let (map_pool, emits) = map_phase(batch, feed, cfg, &map_chunk);
    let map_secs = map_sw.secs();

    // ---- Barrier; finish phase (one holder per key) ----
    let fin_sw = Stopwatch::start();
    let mut shards = collector.into_shards();
    if let (Some(hot), Some(merge)) = (hot_key, merge) {
        // Re-unify the split key: pull every hash-matching entry out of
        // the non-canonical shards and merge it into the canonical one.
        let canonical = super::collector::shard_of(hot, shards.len());
        let mut partials = Vec::new();
        for (si, shard) in shards.iter_mut().enumerate() {
            if si == canonical {
                continue;
            }
            let matching: Vec<K> = shard
                .keys()
                .filter(|k| fxhash(k) == hot)
                .cloned()
                .collect();
            for k in matching {
                if let Some(h) = shard.remove(&k) {
                    partials.push((k, h));
                }
            }
        }
        let mut alloc = cfg.heap.thread_alloc();
        for (k, h) in partials {
            match shards[canonical].entry(k) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    let into = e.get_mut();
                    let absorbed = h.heap_bytes();
                    let before = into.heap_bytes();
                    merge(into, h);
                    let after = into.heap_bytes();
                    // The absorbed partial dies; the target's growth is
                    // charged — the finish-phase free stays balanced.
                    alloc.free(cohorts.collector.holders, absorbed);
                    if after > before {
                        alloc.alloc(cohorts.collector.holders, after - before);
                    } else if before > after {
                        alloc.free(cohorts.collector.holders, before - after);
                    }
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(h);
                }
            }
        }
        alloc.flush();
    }
    let keys = shards.iter().map(|m| m.len()).sum::<usize>() as u64;
    let shuffled_bytes = AtomicU64::new(0);
    let slots: Vec<Mutex<Vec<KeyValue<K, O>>>> =
        (0..shards.len()).map(|_| Mutex::new(Vec::new())).collect();
    batch.run(
        cfg.threads,
        shards
            .into_iter()
            .enumerate()
            .map(|(si, shard)| {
                let slots = &slots;
                let cohorts = &cohorts;
                let shuffled_bytes = &shuffled_bytes;
                move |_wid: usize| {
                    let mut alloc = cfg.heap.thread_alloc();
                    let mut shard_bytes = 0u64;
                    let mut out = Vec::with_capacity(shard.len());
                    for (k, holder) in shard {
                        let hb = holder.heap_bytes();
                        shard_bytes += hb;
                        alloc.free(cohorts.collector.holders, hb);
                        let o = finish(holder);
                        alloc.alloc(cohorts.results, o.heap_bytes());
                        out.push(KeyValue::new(k, o));
                    }
                    alloc.flush();
                    shuffled_bytes.fetch_add(shard_bytes, Ordering::Relaxed);
                    *slots[si].lock().unwrap() = out;
                }
            })
            .collect::<Vec<_>>(),
    );
    let reduce_secs = fin_sw.secs();
    if let Some(o) = batch.pool().obs() {
        o.tracer
            .record_with_dur(SpanKind::ReducePhase, reduce_secs, batch.id().0, slots.len() as u64);
    }

    let results = unwrap_slots(slots);
    let (gc, batch_id, batch_pool) = job_epilogue(cfg, cohorts, &gc_before, batch);
    let metrics = FlowMetrics {
        flow: ExecutionFlow::Combine,
        combiner_source: Some(CombinerSource::Declared),
        fallback_reason: None,
        shuffled_pairs: 0,
        shuffled_holders: keys,
        shuffled_bytes: shuffled_bytes.load(Ordering::Relaxed),
        materialized_in: 0,
        map_secs,
        reduce_secs,
        total_secs: total_sw.secs(),
        emits,
        keys,
        results: results.iter().map(|s| s.len() as u64).sum(),
        gc,
        map_pool,
        batch: batch_id,
        batch_pool,
        cache: None,
        skew: sketch.into_inner().unwrap().finish(emits),
    };
    (results, metrics)
}

/// The keyed list flow: collect every pair, run the holder triple
/// sequentially per key after the barrier (mirrors [`run_reduce_flow`]).
#[allow(clippy::too_many_arguments)]
fn run_keyed_list_flow<I, K, V, H, O>(
    batch: &Batch<'_>,
    pairs: PairFn<'_, I, K, V>,
    init: &(dyn Fn() -> H + Sync),
    fold: &(dyn Fn(&mut H, V) + Sync),
    finish: &(dyn Fn(H) -> O + Sync),
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    fallback_reason: &str,
    n_shards: usize,
    observe: bool,
) -> (Vec<Vec<KeyValue<K, O>>>, FlowMetrics)
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + HeapSized,
    V: Send + HeapSized,
    H: Send + HeapSized,
    O: Send + HeapSized,
{
    let total_sw = Stopwatch::start();
    let cohorts = job_cohorts(cfg);
    let gc_before = cfg.heap.stats();
    let collector: ListCollector<K, V> = ListCollector::new(n_shards);
    let sketch = Mutex::new(SkewSketch::default());

    // ---- Map phase ----
    let map_sw = Stopwatch::start();
    let map_chunk = |items: &[I]| -> u64 {
        let mut alloc = cfg.heap.thread_alloc();
        let mut emits = 0u64;
        let mut tracker = MajorityTracker::new();
        for input in items {
            pairs(input, &mut |k, v| {
                if cfg.scratch_per_emit > 0 {
                    alloc.scratch(cohorts.scratch, cfg.scratch_per_emit);
                }
                if observe {
                    tracker.hit(fxhash(&k));
                }
                collector.emit(k, v, &mut alloc, &cohorts.collector);
                emits += 1;
            });
        }
        alloc.flush();
        if observe {
            let (cand, weight) = tracker.summary();
            sketch.lock().unwrap().absorb(cand, weight);
        }
        emits
    };
    let (map_pool, emits) = map_phase(batch, feed, cfg, &map_chunk);
    let map_secs = map_sw.secs();

    // ---- Barrier; per-key fold over shards ----
    let reduce_sw = Stopwatch::start();
    let keys = collector.key_count() as u64;
    let shards = collector.into_shards();
    let shuffled_bytes = AtomicU64::new(0);
    let slots: Vec<Mutex<Vec<KeyValue<K, O>>>> =
        (0..shards.len()).map(|_| Mutex::new(Vec::new())).collect();
    batch.run(
        cfg.threads,
        shards
            .into_iter()
            .enumerate()
            .map(|(si, shard)| {
                let slots = &slots;
                let cohorts = &cohorts;
                let shuffled_bytes = &shuffled_bytes;
                move |_wid: usize| {
                    let mut alloc = cfg.heap.thread_alloc();
                    let mut shard_bytes = 0u64;
                    let mut out = Vec::with_capacity(shard.len());
                    for (k, values) in shard {
                        let bytes: u64 = values
                            .iter()
                            .map(|v| v.heap_bytes() + super::collector::LIST_SLOT_BYTES)
                            .sum();
                        shard_bytes += bytes;
                        let mut holder = init();
                        for v in values {
                            fold(&mut holder, v);
                        }
                        // The key's list dies once folded (paper Fig. 1).
                        alloc.free(cohorts.collector.intermediate, bytes);
                        let o = finish(holder);
                        alloc.alloc(cohorts.results, o.heap_bytes());
                        out.push(KeyValue::new(k, o));
                    }
                    alloc.flush();
                    shuffled_bytes.fetch_add(shard_bytes, Ordering::Relaxed);
                    *slots[si].lock().unwrap() = out;
                }
            })
            .collect::<Vec<_>>(),
    );
    let reduce_secs = reduce_sw.secs();
    if let Some(o) = batch.pool().obs() {
        o.tracer
            .record_with_dur(SpanKind::ReducePhase, reduce_secs, batch.id().0, slots.len() as u64);
    }

    let results = unwrap_slots(slots);
    let (gc, batch_id, batch_pool) = job_epilogue(cfg, cohorts, &gc_before, batch);
    let metrics = FlowMetrics {
        flow: ExecutionFlow::Reduce,
        combiner_source: None,
        fallback_reason: Some(fallback_reason.to_string()),
        shuffled_pairs: emits,
        shuffled_holders: 0,
        shuffled_bytes: shuffled_bytes.load(Ordering::Relaxed),
        materialized_in: 0,
        map_secs,
        reduce_secs,
        total_secs: total_sw.secs(),
        emits,
        keys,
        results: results.iter().map(|s| s.len() as u64).sum(),
        gc,
        map_pool,
        batch: batch_id,
        batch_pool,
        cache: None,
        skew: sketch.into_inner().unwrap().finish(emits),
    };
    (results, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::reducers::RirReducer;
    use crate::optimizer::builder::canon;

    /// Word-count-shaped mapper over pre-tokenized lines.
    fn wc_mapper(line: &String, em: &mut dyn Emitter<String, i64>) {
        for w in line.split_whitespace() {
            em.emit(w.to_string(), 1);
        }
    }

    fn lines() -> Vec<String> {
        vec![
            "the quick brown fox".into(),
            "the lazy dog".into(),
            "the quick dog".into(),
        ]
    }

    fn sorted(mut v: Vec<KeyValue<String, i64>>) -> Vec<(String, i64)> {
        v.sort_by(|a, b| a.key.cmp(&b.key));
        v.into_iter().map(|kv| (kv.key, kv.value)).collect()
    }

    #[test]
    fn reduce_and_combine_flows_agree() {
        let inputs = lines();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc"));
        let agent = OptimizerAgent::new();

        let cfg_off = JobConfig::fast().with_threads(2).with_optimize(OptimizeMode::Off);
        let (r1, m1) = run_job(&wc_mapper, &reducer, &inputs, &cfg_off, &agent);
        assert_eq!(m1.flow, ExecutionFlow::Reduce);

        let cfg_on = JobConfig::fast().with_threads(2).with_optimize(OptimizeMode::Auto);
        let (r2, m2) = run_job(&wc_mapper, &reducer, &inputs, &cfg_on, &agent);
        assert_eq!(m2.flow, ExecutionFlow::Combine);

        assert_eq!(sorted(r1), sorted(r2));
        assert_eq!(m1.emits, 10);
        assert_eq!(m1.keys, 6);
        assert_eq!(m2.emits, m1.emits);
        assert_eq!(m2.keys, m1.keys);
    }

    #[test]
    fn counts_are_correct() {
        let inputs = lines();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc"));
        let agent = OptimizerAgent::new();
        let (r, _) = run_job(
            &wc_mapper,
            &reducer,
            &inputs,
            &JobConfig::fast().with_threads(4),
            &agent,
        );
        let r = sorted(r);
        assert_eq!(
            r,
            vec![
                ("brown".to_string(), 1),
                ("dog".to_string(), 2),
                ("fox".to_string(), 1),
                ("lazy".to_string(), 1),
                ("quick".to_string(), 2),
                ("the".to_string(), 3),
            ]
        );
    }

    #[test]
    fn non_transformable_reducer_falls_back() {
        let inputs = lines();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::early_exit("ee"));
        let agent = OptimizerAgent::new();
        let (_, m) = run_job(
            &wc_mapper,
            &reducer,
            &inputs,
            &JobConfig::fast().with_optimize(OptimizeMode::Auto),
            &agent,
        );
        assert_eq!(m.flow, ExecutionFlow::Reduce);
        assert!(m.fallback_reason.unwrap().contains("early exit"));
    }

    #[test]
    fn generic_only_suppresses_fast_path_but_matches() {
        let inputs = lines();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc2"));
        let agent = OptimizerAgent::new();
        let (r_fast, m_fast) = run_job(
            &wc_mapper,
            &reducer,
            &inputs,
            &JobConfig::fast().with_optimize(OptimizeMode::Auto),
            &agent,
        );
        let (r_gen, m_gen) = run_job(
            &wc_mapper,
            &reducer,
            &inputs,
            &JobConfig::fast().with_optimize(OptimizeMode::GenericOnly),
            &agent,
        );
        assert_eq!(m_fast.flow, ExecutionFlow::Combine);
        assert_eq!(m_gen.flow, ExecutionFlow::Combine);
        assert_eq!(sorted(r_fast), sorted(r_gen));
    }

    #[test]
    fn empty_input_runs() {
        let inputs: Vec<String> = Vec::new();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc3"));
        let agent = OptimizerAgent::new();
        let (r, m) = run_job(&wc_mapper, &reducer, &inputs, &JobConfig::fast(), &agent);
        assert!(r.is_empty());
        assert_eq!(m.emits, 0);
    }

    #[test]
    fn combine_flow_allocates_less() {
        // The paper's mechanism end-to-end: many values per key.
        let inputs: Vec<String> =
            (0..200).map(|_| "a b c a b a".to_string()).collect();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc4"));
        let agent = OptimizerAgent::new();

        let heap_off = crate::memsim::SimHeap::new(crate::memsim::HeapParams::no_injection());
        let cfg_off = JobConfig::new()
            .with_heap(heap_off)
            .with_optimize(OptimizeMode::Off)
            .with_threads(2);
        let (_, m_off) = run_job(&wc_mapper, &reducer, &inputs, &cfg_off, &agent);

        let heap_on = crate::memsim::SimHeap::new(crate::memsim::HeapParams::no_injection());
        let cfg_on = JobConfig::new()
            .with_heap(heap_on)
            .with_optimize(OptimizeMode::Auto)
            .with_threads(2);
        let (_, m_on) = run_job(&wc_mapper, &reducer, &inputs, &cfg_on, &agent);

        assert!(
            m_on.gc.allocated_objects * 10 < m_off.gc.allocated_objects,
            "combine flow must allocate ≥10× fewer objects: {} vs {}",
            m_on.gc.allocated_objects,
            m_off.gc.allocated_objects
        );
    }

    #[test]
    fn stream_feed_matches_slice_feed() {
        let inputs = lines();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc5"));
        let agent = OptimizerAgent::new();
        let cfg = JobConfig::fast().with_threads(3);
        let pool = WorkerPool::new(3);

        let (from_slice, ms) = run_job_on(
            &pool,
            &wc_mapper,
            &reducer,
            Feed::Slice(&inputs),
            &cfg,
            &agent,
        );

        let mut remaining = inputs.clone();
        remaining.reverse(); // pop() below restores original order
        let stream = Feed::Stream(Box::new(move || remaining.pop().map(|l| vec![l])));
        let (from_stream, mm) = run_job_on(&pool, &wc_mapper, &reducer, stream, &cfg, &agent);

        assert_eq!(sorted(from_slice), sorted(from_stream));
        assert_eq!(ms.emits, mm.emits);
        assert_eq!(ms.keys, mm.keys);
    }

    #[test]
    fn hot_key_split_matches_static_declared_flow() {
        let pool = WorkerPool::new(4);
        let cfg = JobConfig::fast().with_threads(4);
        // 90 % of the pairs hit key 0 — the shape the split targets.
        let inputs: Vec<(i64, i64)> = (0..4096i64)
            .map(|i| (if i % 10 == 0 { 1 + i % 7 } else { 0 }, 1))
            .collect();
        let pairs: PairFn<'_, (i64, i64), i64, i64> = &|p, sink| sink(p.0, p.1);
        let run = |ctx: KeyedAdaptive<'_, i64>| {
            let agent = OptimizerAgent::new();
            run_keyed_sharded_adaptive(
                &pool,
                "sum",
                true,
                true,
                pairs,
                || 0i64,
                |h: &mut i64, v: i64| *h += v,
                |h| h,
                Feed::Slice(&inputs),
                &cfg,
                &agent,
                ctx,
            )
        };
        let (static_out, m_static) = run(KeyedAdaptive::default());
        assert_eq!(m_static.flow, ExecutionFlow::Combine);
        assert!(m_static.skew.is_none(), "static run does not observe");

        let merge: &(dyn Fn(&mut i64, i64) + Sync) = &|a, b| *a += b;
        let adapt = StageAdapt {
            hot_key: Some(fxhash(&0i64)),
            samples: 1,
            ..StageAdapt::default()
        };
        let (split_out, m_split) = run(KeyedAdaptive {
            adapt: Some(&adapt),
            observe: true,
            merge: Some(merge),
        });
        assert_eq!(m_split.flow, ExecutionFlow::Combine);
        assert_eq!(m_split.keys, m_static.keys, "split partials must re-merge");
        let skew = m_split.skew.expect("observing run collects the sketch");
        assert_eq!(skew.hot_hash, fxhash(&0i64));
        assert!(skew.hot_support * 2 >= skew.emits);

        let canonical = |out: Vec<Vec<KeyValue<i64, i64>>>| {
            let mut v: Vec<(i64, i64)> = concat_shards(out)
                .into_iter()
                .map(|kv| (kv.key, kv.value))
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(canonical(static_out), canonical(split_out));
    }

    #[test]
    fn shard_override_preserves_results() {
        let inputs = lines();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc7"));
        let agent = OptimizerAgent::new();
        let cfg = JobConfig::fast().with_threads(2);
        let pool = WorkerPool::new(2);
        let (r_static, _) = run_job_on(
            &pool,
            &wc_mapper,
            &reducer,
            Feed::Slice(&inputs),
            &cfg,
            &agent,
        );
        let adapt = StageAdapt {
            shard_override: Some(16),
            samples: 1,
            ..StageAdapt::default()
        };
        let (shards, m) = run_job_sharded_adaptive(
            &pool,
            &wc_mapper,
            &reducer,
            Feed::Slice(&inputs),
            &cfg,
            &agent,
            Some(&adapt),
        );
        assert_eq!(shards.len(), 16, "collector takes the observed shard count");
        assert_eq!(m.keys, 6);
        assert_eq!(sorted(r_static), sorted(concat_shards(shards)));
    }

    #[test]
    fn shard_order_concatenation_is_stable() {
        // Same inputs, same config → same output order (single worker
        // makes per-shard insertion order deterministic too).
        let inputs: Vec<String> = (0..50).map(|i| format!("w{} w{}", i % 7, i % 11)).collect();
        let reducer: RirReducer<String, i64> = RirReducer::new(canon::sum_i64("wc6"));
        let agent = OptimizerAgent::new();
        let cfg = JobConfig::fast().with_threads(1);
        let (a, _) = run_job(&wc_mapper, &reducer, &inputs, &cfg, &agent);
        let (b, _) = run_job(&wc_mapper, &reducer, &inputs, &cfg, &agent);
        let a: Vec<_> = a.into_iter().map(|kv| (kv.key, kv.value)).collect();
        let b: Vec<_> = b.into_iter().map(|kv| (kv.key, kv.value)).collect();
        assert_eq!(a, b);
    }
}
