//! The physical planner — lowers a lazy [`crate::api::plan::Dataset`]'s
//! logical stage list into a [`PhysicalPlan`] and carries the state one
//! plan execution threads through its stages.
//!
//! The paper's optimizer sees one reducer class at a time; the planner is
//! where the framework finally sees a *whole pipeline* at once (the
//! cross-stage view MANIMAL-style pre-execution analysis exploits). It
//! delegates the actual placement decisions to the session
//! [`OptimizerAgent`]'s whole-plan pass ([`OptimizerAgent::plan`]) so the
//! decision-making — and its statistics — live with the rest of the
//! semantic optimizer, then packages the result for the executor:
//!
//! * which element-wise stages compose into their consumer's map phase
//!   ([`StageDecision::Fuse`]);
//! * which reduce handoffs stream shard outputs instead of round-tripping
//!   through a materialized `JobOutput` ([`StageDecision::StreamInput`]).
//!
//! [`PlanExec`] is the per-collect execution context: the session's
//! worker pool and agent (so every stage reuses one pool, like eager
//! session jobs), the lowered plan, and the per-stage metrics + plan-wide
//! materialization accounting that become the final
//! [`crate::api::plan::PlanReport`]. One `PlanExec` exists per `collect`
//! call and owns all of that run's mutable state, so concurrent plans on
//! one session report isolated metrics — each stage they run submits its
//! own tagged batch ([`crate::coordinator::scheduler::Batch`]) to the
//! shared multi-tenant pool.

use std::ops::Range;

use crate::api::config::OptimizeMode;
use crate::api::plan::{PlanReport, StageInfo, StageKind};
use crate::cache::{fingerprint, CacheActivity, Fingerprint, MaterializationCache};
use crate::coordinator::pipeline::FlowMetrics;
use crate::coordinator::scheduler::WorkerPool;
use crate::optimizer::agent::{OptimizerAgent, StageDecision, StageShape};

fn is_element_wise(kind: StageKind) -> bool {
    matches!(kind, StageKind::Map | StageKind::Filter | StageKind::FlatMap)
}

/// The lowered plan: one placement per logical stage, plus the counts the
/// report surfaces and the prefix fingerprints cache cut points resolve
/// against.
#[derive(Clone, Debug)]
pub struct PhysicalPlan {
    /// Placement per logical stage, parallel to the recorded stage list.
    pub decisions: Vec<StageDecision>,
    /// Element-wise stages composed into a downstream map phase.
    pub fused_ops: usize,
    /// Reduce→stage handoffs that stream shard outputs.
    pub streamed_handoffs: usize,
    /// Cumulative structural fingerprint after each stage (see
    /// [`crate::cache::fingerprint`]); `prefix_fps[i]` identifies the
    /// prefix `stages[0..=i]`. Computed — and address identities
    /// registered — only for cacheable plans that actually mark a cut
    /// (empty otherwise, so plans that never cache cost the session
    /// registry nothing).
    pub prefix_fps: Vec<u64>,
    /// Whether prefix fingerprints identify real computation: requires an
    /// identity-bearing `Source` root (co-group-rooted plans and stream
    /// sources lower with `cacheable: false`, and their cut points
    /// materialize without touching the cache).
    pub cacheable: bool,
}

/// Lower a logical stage list to a physical plan via the agent's
/// whole-plan pass. Plans are linear chains today, so "does this reduce
/// follow a reduce" is simply "is there any upstream reduce stage".
///
/// Fusion is all-or-nothing per element-wise chain (a half-fused chain
/// would still materialize), so one optimizer-off stage demotes its whole
/// contiguous run before the agent decides — keeping the decisions, the
/// plan report, and the agent's statistics faithful to what the executor
/// actually does under mixed per-stage modes. A chain feeding a
/// [`StageKind::Cache`] cut is demoted the same way: the cut *is* a
/// materialization point (that is what gets stored), so reporting those
/// ops as fused would be dishonest.
pub fn lower(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
) -> PhysicalPlan {
    lower_impl(stages, agent, registry, true)
}

fn lower_impl(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
    record: bool,
) -> PhysicalPlan {
    // Mark every element-wise stage whose contiguous run contains an
    // optimizer-off stage, or whose run feeds a cache cut (the chain
    // materializes into the stored entry).
    let mut chain_off = vec![false; stages.len()];
    let mut i = 0;
    while i < stages.len() {
        if is_element_wise(stages[i].kind) {
            let start = i;
            let mut any_off = false;
            while i < stages.len() && is_element_wise(stages[i].kind) {
                any_off |= matches!(stages[i].optimize, OptimizeMode::Off);
                i += 1;
            }
            let feeds_cut = stages.get(i).is_some_and(|s| s.kind == StageKind::Cache);
            if any_off || feeds_cut {
                for flag in &mut chain_off[start..i] {
                    *flag = true;
                }
            }
        } else {
            i += 1;
        }
    }

    let mut shapes = Vec::with_capacity(stages.len());
    let mut seen_reduce = false;
    for (i, stage) in stages.iter().enumerate() {
        shapes.push(match stage.kind {
            StageKind::Source => StageShape::Source,
            StageKind::Map | StageKind::Filter | StageKind::FlatMap => StageShape::ElementWise {
                mode: if chain_off[i] {
                    OptimizeMode::Off
                } else {
                    stage.optimize
                },
            },
            // Keyed aggregation is a reduce-shaped barrier to the
            // whole-plan pass: it can fuse its upstream chain and stream
            // a reduce handoff exactly like `map_reduce`; whether its
            // *combining* rewrite fires is decided per stage by the
            // agent's declared channel at execution time (mirroring the
            // per-class inferred path).
            StageKind::MapReduce | StageKind::KeyedAggregate => {
                let shape = StageShape::Reduce {
                    mode: stage.optimize,
                    follows_reduce: seen_reduce,
                };
                seen_reduce = true;
                shape
            }
            // A co-group executes both inputs as sub-plans of its own, so
            // the outer plan never streams into it — but its *output* is
            // sharded like any reduce stage, so downstream stages may.
            StageKind::CoGroup => {
                seen_reduce = true;
                StageShape::Reduce {
                    mode: stage.optimize,
                    follows_reduce: false,
                }
            }
            // A cache cut holds sharded materialized data whichever way
            // it resolves, so downstream reduces may stream from it; the
            // cut itself needs no placement decision from the agent
            // (source-shaped: nothing to decide).
            StageKind::Cache => {
                seen_reduce = true;
                StageShape::Source
            }
        });
    }
    let decisions = if record {
        agent.plan(&shapes)
    } else {
        agent.plan_preview(&shapes)
    };
    let fused_ops = decisions
        .iter()
        .filter(|d| matches!(d, StageDecision::Fuse))
        .count();
    let streamed_handoffs = decisions
        .iter()
        .filter(|d| matches!(d, StageDecision::StreamInput))
        .count();
    // Fingerprint only plans that can and do cache: a cacheable root AND
    // at least one cut point. Everything else skips the hashing and,
    // more importantly, never registers its address identities with the
    // session registry.
    let has_cut = stages.iter().any(|s| s.kind == StageKind::Cache);
    let cacheable = has_cut && fingerprint::cacheable(stages);
    let prefix_fps = if cacheable || !record {
        // `!record` is the observational `describe()` pass, which shows
        // fingerprints even for cut-less plans.
        fingerprint::prefix_fingerprints(stages, registry)
    } else {
        Vec::new()
    };
    PhysicalPlan {
        decisions,
        fused_ops,
        streamed_handoffs,
        prefix_fps,
        cacheable,
    }
}

fn kind_label(kind: StageKind) -> &'static str {
    match kind {
        StageKind::Source => "source",
        StageKind::Map => "map",
        StageKind::Filter => "filter",
        StageKind::FlatMap => "flat_map",
        StageKind::MapReduce => "map_reduce",
        StageKind::KeyedAggregate => "keyed_aggregate",
        StageKind::CoGroup => "co_group",
        StageKind::Cache => "cache",
    }
}

fn decision_label(d: &StageDecision) -> &'static str {
    match d {
        StageDecision::Input => "input",
        StageDecision::Fuse => "fuse",
        StageDecision::Materialize => "materialize",
        StageDecision::StreamInput => "stream-input",
        StageDecision::MaterializeInput => "materialize-input",
    }
}

/// Render a lowered plan for humans ([`Dataset::explain`]): stage kinds
/// and names, the whole-plan pass's decisions, prefix fingerprints, and
/// cache cut points. Uses the agent's non-recording preview, so calling
/// it leaves the optimizer statistics untouched.
///
/// [`Dataset::explain`]: crate::api::plan::Dataset::explain
pub(crate) fn describe(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
) -> String {
    use std::fmt::Write;
    let plan = lower_impl(stages, agent, registry, false);
    // `plan.cacheable` additionally requires a cut; for display we care
    // about whether the *root* is identifiable at all.
    let root_identified = fingerprint::cacheable(stages);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "plan: {} stage(s), prefix fingerprints {}",
        stages.len(),
        if root_identified {
            "active"
        } else {
            "inactive (unidentified source)"
        }
    );
    for (i, s) in stages.iter().enumerate() {
        let decision = plan
            .decisions
            .get(i)
            .map(decision_label)
            .unwrap_or("?");
        let fp = plan.prefix_fps.get(i).copied().unwrap_or(0);
        if s.kind == StageKind::Cache {
            let _ = writeln!(
                out,
                "  [{i}] cache            — cut point, prefix fp {}{}",
                Fingerprint(fp),
                if root_identified { "" } else { " (inactive)" },
            );
        } else {
            let _ = writeln!(
                out,
                "  [{i}] {:<16} {:<24} {:<12} {:?}  fp {}",
                kind_label(s.kind),
                s.name,
                decision,
                s.optimize,
                Fingerprint(fp),
            );
        }
    }
    let _ = writeln!(
        out,
        "fused element-wise ops: {}; streamed handoffs: {}",
        plan.fused_ops, plan.streamed_handoffs
    );
    out
}

/// Execution context for one plan run (one `collect` call): the session
/// resources every stage shares, the lowered plan, and the running
/// measurements.
pub struct PlanExec<'rt> {
    pub(crate) pool: &'rt WorkerPool,
    pub(crate) agent: &'rt OptimizerAgent,
    plan: PhysicalPlan,
    stage_metrics: Vec<FlowMetrics>,
    materialized: u64,
    /// Rewrite counts absorbed from sub-plans (two-input stages execute
    /// each input as its own lowered plan and merge the accounting here).
    absorbed_fused: usize,
    absorbed_streamed: usize,
    /// Cache activity since the last executed stage, attached to the next
    /// stage's metrics (the stage that consumed the resolved input).
    pending_cache: Option<CacheActivity>,
    /// Plan-total cache activity (the [`PlanReport::cache`] field).
    cache_total: CacheActivity,
}

impl<'rt> PlanExec<'rt> {
    pub(crate) fn new(
        pool: &'rt WorkerPool,
        agent: &'rt OptimizerAgent,
        plan: PhysicalPlan,
    ) -> Self {
        PlanExec {
            pool,
            agent,
            plan,
            stage_metrics: Vec::new(),
            materialized: 0,
            absorbed_fused: 0,
            absorbed_streamed: 0,
            pending_cache: None,
            cache_total: CacheActivity::default(),
        }
    }

    /// True when every element-wise stage in `range` fuses into its
    /// consumer (vacuously true for an empty chain — a direct handoff).
    pub(crate) fn chain_fused(&self, range: &Range<usize>) -> bool {
        range
            .clone()
            .all(|i| matches!(self.plan.decisions.get(i), Some(StageDecision::Fuse)))
    }

    /// True when the reduce stage at logical index `index` consumes its
    /// upstream's shard outputs as a stream.
    pub(crate) fn stream_input(&self, index: usize) -> bool {
        matches!(
            self.plan.decisions.get(index),
            Some(StageDecision::StreamInput)
        )
    }

    /// The prefix fingerprint a cache cut at logical index `index`
    /// resolves against, or `None` when the plan has no identified source
    /// (the cut then materializes without touching the cache).
    pub(crate) fn cut_fingerprint(&self, index: usize) -> Option<Fingerprint> {
        if self.plan.cacheable {
            self.plan.prefix_fps.get(index).map(|&h| Fingerprint(h))
        } else {
            None
        }
    }

    /// Record cache activity from resolving a cut point: totalled into
    /// the plan report, and attached to the next executed stage's metrics
    /// (the stage that consumed the resolved input).
    pub(crate) fn note_cache(&mut self, activity: CacheActivity) {
        self.cache_total.add(&activity);
        self.pending_cache
            .get_or_insert_with(CacheActivity::default)
            .add(&activity);
    }

    /// Record `n` elements materialized into a plan-level intermediate.
    pub(crate) fn note_materialized(&mut self, n: u64) {
        self.materialized += n;
    }

    /// Record one executed reduce stage's metrics.
    pub(crate) fn push_metrics(&mut self, mut metrics: FlowMetrics) {
        metrics.cache = self.pending_cache.take();
        self.stage_metrics.push(metrics);
    }

    /// Merge a sub-plan's report into this execution (two-input stages:
    /// each co-group input runs as its own lowered plan). Stage metrics
    /// append in execution order; rewrite and materialization accounting
    /// add up, so the outer [`PlanReport`] covers the whole tree.
    pub(crate) fn absorb(&mut self, report: PlanReport) {
        self.absorbed_fused += report.fused_ops;
        self.absorbed_streamed += report.streamed_handoffs;
        self.materialized += report.materialized_pairs;
        self.cache_total.add(&report.cache);
        self.stage_metrics.extend(report.stage_metrics);
    }

    pub(crate) fn into_report(self) -> PlanReport {
        PlanReport {
            stage_metrics: self.stage_metrics,
            fused_ops: self.plan.fused_ops + self.absorbed_fused,
            streamed_handoffs: self.plan.streamed_handoffs + self.absorbed_streamed,
            materialized_pairs: self.materialized,
            cache: self.cache_total,
            stream: None,
            govern: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::config::OptimizeMode;

    fn info(kind: StageKind, mode: OptimizeMode) -> StageInfo {
        StageInfo {
            kind,
            name: "t".into(),
            optimize: mode,
            token: Some(crate::api::plan::StageToken::Stable(1)),
        }
    }

    fn registry() -> MaterializationCache {
        MaterializationCache::new()
    }

    #[test]
    fn lower_marks_fusion_and_streaming() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Filter, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        assert_eq!(plan.fused_ops, 1);
        assert_eq!(plan.streamed_handoffs, 1);
        assert_eq!(plan.decisions[1], StageDecision::MaterializeInput);
        assert_eq!(plan.decisions[3], StageDecision::StreamInput);
    }

    #[test]
    fn lower_off_mode_is_fully_materialized() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
            info(StageKind::Map, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
        ];
        let plan = lower(&stages, &agent, &registry());
        assert_eq!(plan.fused_ops, 0);
        assert_eq!(plan.streamed_handoffs, 0);
    }

    #[test]
    fn mixed_mode_chain_is_demoted_whole() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Map, OptimizeMode::Auto),
            info(StageKind::Filter, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        // One Off stage demotes the whole chain…
        assert_eq!(plan.decisions[2], StageDecision::Materialize);
        assert_eq!(plan.decisions[3], StageDecision::Materialize);
        assert_eq!(plan.fused_ops, 0);
        // …but the Auto reduce still streams its handoff: the chain
        // stages, not the handoff, are what the Off stage governs.
        assert_eq!(plan.decisions[4], StageDecision::StreamInput);
        assert_eq!(plan.streamed_handoffs, 1);
    }

    #[test]
    fn keyed_stages_lower_like_reduces_and_cogroups_never_stream_in() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::CoGroup, OptimizeMode::Auto),
            info(StageKind::FlatMap, OptimizeMode::Auto),
            info(StageKind::KeyedAggregate, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        // The co-group materializes its own inputs (sub-plans), but its
        // sharded output streams into the downstream keyed aggregate.
        assert_eq!(plan.decisions[0], StageDecision::MaterializeInput);
        assert_eq!(plan.decisions[1], StageDecision::Fuse);
        assert_eq!(plan.decisions[2], StageDecision::StreamInput);
        assert_eq!((plan.fused_ops, plan.streamed_handoffs), (1, 1));
    }

    #[test]
    fn cache_cut_streams_downstream_and_demotes_its_chain() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::Map, OptimizeMode::Auto),
            info(StageKind::Cache, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        // The chain feeding the cut materializes (into the entry)…
        assert_eq!(plan.decisions[1], StageDecision::Materialize);
        assert_eq!(plan.fused_ops, 0);
        // …and the cut's sharded output streams into the downstream
        // reduce like any barrier's would.
        assert_eq!(plan.decisions[3], StageDecision::StreamInput);
        assert!(plan.cacheable);
        assert_eq!(plan.prefix_fps.len(), 4);
    }

    #[test]
    fn unidentified_sources_lower_uncacheable() {
        let agent = OptimizerAgent::new();
        let mut stages = vec![
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::Cache, OptimizeMode::Auto),
        ];
        stages[0].token = None; // stream source
        assert!(!lower(&stages, &agent, &registry()).cacheable);
        let cogroup = [info(StageKind::CoGroup, OptimizeMode::Auto)];
        assert!(!lower(&cogroup, &agent, &registry()).cacheable);
    }

    #[test]
    fn describe_renders_decisions_and_cuts_without_stats() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Cache, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let text = describe(&stages, &agent, &registry());
        assert!(text.contains("cache"), "{text}");
        assert!(text.contains("cut point"), "{text}");
        assert!(text.contains("stream-input"), "{text}");
        assert!(text.contains("fp "), "{text}");
        assert_eq!(agent.stats().plans, 0, "describe must not record a plan pass");
    }

    #[test]
    fn exec_chain_fused_is_vacuous_on_empty_ranges() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
        ];
        let plan = lower(&stages, &agent, &registry());
        let pool = WorkerPool::new(1);
        let exec = PlanExec::new(&pool, &agent, plan);
        assert!(exec.chain_fused(&(1..1)), "empty chain is a direct handoff");
        assert!(!exec.stream_input(1));
    }
}
