//! The physical planner — lowers a lazy [`crate::api::plan::Dataset`]'s
//! logical stage list into a [`PhysicalPlan`] and carries the state one
//! plan execution threads through its stages.
//!
//! The paper's optimizer sees one reducer class at a time; the planner is
//! where the framework finally sees a *whole pipeline* at once (the
//! cross-stage view MANIMAL-style pre-execution analysis exploits). It
//! delegates the actual placement decisions to the session
//! [`OptimizerAgent`]'s whole-plan pass ([`OptimizerAgent::plan`]) so the
//! decision-making — and its statistics — live with the rest of the
//! semantic optimizer, then packages the result for the executor:
//!
//! * which element-wise stages compose into their consumer's map phase
//!   ([`StageDecision::Fuse`]);
//! * which reduce handoffs stream shard outputs instead of round-tripping
//!   through a materialized `JobOutput` ([`StageDecision::StreamInput`]).
//!
//! [`PlanExec`] is the per-collect execution context: the session's
//! worker pool and agent (so every stage reuses one pool, like eager
//! session jobs), the lowered plan, and the per-stage metrics + plan-wide
//! materialization accounting that become the final
//! [`crate::api::plan::PlanReport`]. One `PlanExec` exists per `collect`
//! call and owns all of that run's mutable state, so concurrent plans on
//! one session report isolated metrics — each stage they run submits its
//! own tagged batch ([`crate::coordinator::scheduler::Batch`]) to the
//! shared multi-tenant pool.

use std::ops::Range;

use crate::api::config::OptimizeMode;
use crate::api::plan::{PlanReport, StageInfo, StageKind};
use crate::coordinator::pipeline::FlowMetrics;
use crate::coordinator::scheduler::WorkerPool;
use crate::optimizer::agent::{OptimizerAgent, StageDecision, StageShape};

fn is_element_wise(kind: StageKind) -> bool {
    matches!(kind, StageKind::Map | StageKind::Filter | StageKind::FlatMap)
}

/// The lowered plan: one placement per logical stage, plus the counts the
/// report surfaces.
#[derive(Clone, Debug)]
pub struct PhysicalPlan {
    /// Placement per logical stage, parallel to the recorded stage list.
    pub decisions: Vec<StageDecision>,
    /// Element-wise stages composed into a downstream map phase.
    pub fused_ops: usize,
    /// Reduce→stage handoffs that stream shard outputs.
    pub streamed_handoffs: usize,
}

/// Lower a logical stage list to a physical plan via the agent's
/// whole-plan pass. Plans are linear chains today, so "does this reduce
/// follow a reduce" is simply "is there any upstream reduce stage".
///
/// Fusion is all-or-nothing per element-wise chain (a half-fused chain
/// would still materialize), so one optimizer-off stage demotes its whole
/// contiguous run before the agent decides — keeping the decisions, the
/// plan report, and the agent's statistics faithful to what the executor
/// actually does under mixed per-stage modes.
pub fn lower(stages: &[StageInfo], agent: &OptimizerAgent) -> PhysicalPlan {
    // Mark every element-wise stage whose contiguous run contains an
    // optimizer-off stage.
    let mut chain_off = vec![false; stages.len()];
    let mut i = 0;
    while i < stages.len() {
        if is_element_wise(stages[i].kind) {
            let start = i;
            let mut any_off = false;
            while i < stages.len() && is_element_wise(stages[i].kind) {
                any_off |= matches!(stages[i].optimize, OptimizeMode::Off);
                i += 1;
            }
            if any_off {
                for flag in &mut chain_off[start..i] {
                    *flag = true;
                }
            }
        } else {
            i += 1;
        }
    }

    let mut shapes = Vec::with_capacity(stages.len());
    let mut seen_reduce = false;
    for (i, stage) in stages.iter().enumerate() {
        shapes.push(match stage.kind {
            StageKind::Source => StageShape::Source,
            StageKind::Map | StageKind::Filter | StageKind::FlatMap => StageShape::ElementWise {
                mode: if chain_off[i] {
                    OptimizeMode::Off
                } else {
                    stage.optimize
                },
            },
            // Keyed aggregation is a reduce-shaped barrier to the
            // whole-plan pass: it can fuse its upstream chain and stream
            // a reduce handoff exactly like `map_reduce`; whether its
            // *combining* rewrite fires is decided per stage by the
            // agent's declared channel at execution time (mirroring the
            // per-class inferred path).
            StageKind::MapReduce | StageKind::KeyedAggregate => {
                let shape = StageShape::Reduce {
                    mode: stage.optimize,
                    follows_reduce: seen_reduce,
                };
                seen_reduce = true;
                shape
            }
            // A co-group executes both inputs as sub-plans of its own, so
            // the outer plan never streams into it — but its *output* is
            // sharded like any reduce stage, so downstream stages may.
            StageKind::CoGroup => {
                seen_reduce = true;
                StageShape::Reduce {
                    mode: stage.optimize,
                    follows_reduce: false,
                }
            }
        });
    }
    let decisions = agent.plan(&shapes);
    let fused_ops = decisions
        .iter()
        .filter(|d| matches!(d, StageDecision::Fuse))
        .count();
    let streamed_handoffs = decisions
        .iter()
        .filter(|d| matches!(d, StageDecision::StreamInput))
        .count();
    PhysicalPlan {
        decisions,
        fused_ops,
        streamed_handoffs,
    }
}

/// Execution context for one plan run (one `collect` call): the session
/// resources every stage shares, the lowered plan, and the running
/// measurements.
pub struct PlanExec<'rt> {
    pub(crate) pool: &'rt WorkerPool,
    pub(crate) agent: &'rt OptimizerAgent,
    plan: PhysicalPlan,
    stage_metrics: Vec<FlowMetrics>,
    materialized: u64,
    /// Rewrite counts absorbed from sub-plans (two-input stages execute
    /// each input as its own lowered plan and merge the accounting here).
    absorbed_fused: usize,
    absorbed_streamed: usize,
}

impl<'rt> PlanExec<'rt> {
    pub(crate) fn new(
        pool: &'rt WorkerPool,
        agent: &'rt OptimizerAgent,
        plan: PhysicalPlan,
    ) -> Self {
        PlanExec {
            pool,
            agent,
            plan,
            stage_metrics: Vec::new(),
            materialized: 0,
            absorbed_fused: 0,
            absorbed_streamed: 0,
        }
    }

    /// True when every element-wise stage in `range` fuses into its
    /// consumer (vacuously true for an empty chain — a direct handoff).
    pub(crate) fn chain_fused(&self, range: &Range<usize>) -> bool {
        range
            .clone()
            .all(|i| matches!(self.plan.decisions.get(i), Some(StageDecision::Fuse)))
    }

    /// True when the reduce stage at logical index `index` consumes its
    /// upstream's shard outputs as a stream.
    pub(crate) fn stream_input(&self, index: usize) -> bool {
        matches!(
            self.plan.decisions.get(index),
            Some(StageDecision::StreamInput)
        )
    }

    /// Record `n` elements materialized into a plan-level intermediate.
    pub(crate) fn note_materialized(&mut self, n: u64) {
        self.materialized += n;
    }

    /// Record one executed reduce stage's metrics.
    pub(crate) fn push_metrics(&mut self, metrics: FlowMetrics) {
        self.stage_metrics.push(metrics);
    }

    /// Merge a sub-plan's report into this execution (two-input stages:
    /// each co-group input runs as its own lowered plan). Stage metrics
    /// append in execution order; rewrite and materialization accounting
    /// add up, so the outer [`PlanReport`] covers the whole tree.
    pub(crate) fn absorb(&mut self, report: PlanReport) {
        self.absorbed_fused += report.fused_ops;
        self.absorbed_streamed += report.streamed_handoffs;
        self.materialized += report.materialized_pairs;
        self.stage_metrics.extend(report.stage_metrics);
    }

    pub(crate) fn into_report(self) -> PlanReport {
        PlanReport {
            stage_metrics: self.stage_metrics,
            fused_ops: self.plan.fused_ops + self.absorbed_fused,
            streamed_handoffs: self.plan.streamed_handoffs + self.absorbed_streamed,
            materialized_pairs: self.materialized,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::config::OptimizeMode;

    fn info(kind: StageKind, mode: OptimizeMode) -> StageInfo {
        StageInfo {
            kind,
            name: "t".into(),
            optimize: mode,
        }
    }

    #[test]
    fn lower_marks_fusion_and_streaming() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Filter, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent);
        assert_eq!(plan.fused_ops, 1);
        assert_eq!(plan.streamed_handoffs, 1);
        assert_eq!(plan.decisions[1], StageDecision::MaterializeInput);
        assert_eq!(plan.decisions[3], StageDecision::StreamInput);
    }

    #[test]
    fn lower_off_mode_is_fully_materialized() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
            info(StageKind::Map, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
        ];
        let plan = lower(&stages, &agent);
        assert_eq!(plan.fused_ops, 0);
        assert_eq!(plan.streamed_handoffs, 0);
    }

    #[test]
    fn mixed_mode_chain_is_demoted_whole() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Map, OptimizeMode::Auto),
            info(StageKind::Filter, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent);
        // One Off stage demotes the whole chain…
        assert_eq!(plan.decisions[2], StageDecision::Materialize);
        assert_eq!(plan.decisions[3], StageDecision::Materialize);
        assert_eq!(plan.fused_ops, 0);
        // …but the Auto reduce still streams its handoff: the chain
        // stages, not the handoff, are what the Off stage governs.
        assert_eq!(plan.decisions[4], StageDecision::StreamInput);
        assert_eq!(plan.streamed_handoffs, 1);
    }

    #[test]
    fn keyed_stages_lower_like_reduces_and_cogroups_never_stream_in() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::CoGroup, OptimizeMode::Auto),
            info(StageKind::FlatMap, OptimizeMode::Auto),
            info(StageKind::KeyedAggregate, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent);
        // The co-group materializes its own inputs (sub-plans), but its
        // sharded output streams into the downstream keyed aggregate.
        assert_eq!(plan.decisions[0], StageDecision::MaterializeInput);
        assert_eq!(plan.decisions[1], StageDecision::Fuse);
        assert_eq!(plan.decisions[2], StageDecision::StreamInput);
        assert_eq!((plan.fused_ops, plan.streamed_handoffs), (1, 1));
    }

    #[test]
    fn exec_chain_fused_is_vacuous_on_empty_ranges() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
        ];
        let plan = lower(&stages, &agent);
        let pool = WorkerPool::new(1);
        let exec = PlanExec::new(&pool, &agent, plan);
        assert!(exec.chain_fused(&(1..1)), "empty chain is a direct handoff");
        assert!(!exec.stream_input(1));
    }
}
