//! The physical planner — lowers a lazy [`crate::api::plan::Dataset`]'s
//! logical stage list into a [`PhysicalPlan`] and carries the state one
//! plan execution threads through its stages.
//!
//! The paper's optimizer sees one reducer class at a time; the planner is
//! where the framework finally sees a *whole pipeline* at once (the
//! cross-stage view MANIMAL-style pre-execution analysis exploits). It
//! delegates the actual placement decisions to the session
//! [`OptimizerAgent`]'s whole-plan pass ([`OptimizerAgent::plan`]) so the
//! decision-making — and its statistics — live with the rest of the
//! semantic optimizer, then packages the result for the executor:
//!
//! * which element-wise stages compose into their consumer's map phase
//!   ([`StageDecision::Fuse`]);
//! * which reduce handoffs stream shard outputs instead of round-tripping
//!   through a materialized `JobOutput` ([`StageDecision::StreamInput`]).
//!
//! [`PlanExec`] is the per-collect execution context: the session's
//! worker pool and agent (so every stage reuses one pool, like eager
//! session jobs), the lowered plan, and the per-stage metrics + plan-wide
//! materialization accounting that become the final
//! [`crate::api::plan::PlanReport`]. One `PlanExec` exists per `collect`
//! call and owns all of that run's mutable state, so concurrent plans on
//! one session report isolated metrics — each stage they run submits its
//! own tagged batch ([`crate::coordinator::scheduler::Batch`]) to the
//! shared multi-tenant pool.

use std::ops::Range;

use crate::api::config::OptimizeMode;
use crate::api::plan::{PlanReport, StageInfo, StageKind};
use crate::cache::{fingerprint, CacheActivity, Fingerprint, MaterializationCache};
use crate::coordinator::collector::shard_count;
use crate::coordinator::pipeline::FlowMetrics;
use crate::coordinator::scheduler::WorkerPool;
use crate::optimizer::agent::{OptimizerAgent, StageDecision, StageShape};
use crate::stats::{self, AdaptationReport, AdaptiveDecision, StageAdapt, StatsStore};

fn is_element_wise(kind: StageKind) -> bool {
    matches!(kind, StageKind::Map | StageKind::Filter | StageKind::FlatMap)
}

/// The lowered plan: one placement per logical stage, plus the counts the
/// report surfaces and the prefix fingerprints cache cut points resolve
/// against.
#[derive(Clone, Debug)]
pub struct PhysicalPlan {
    /// Placement per logical stage, parallel to the recorded stage list.
    pub decisions: Vec<StageDecision>,
    /// Element-wise stages composed into a downstream map phase.
    pub fused_ops: usize,
    /// Reduce→stage handoffs that stream shard outputs.
    pub streamed_handoffs: usize,
    /// Cumulative structural fingerprint after each stage (see
    /// [`crate::cache::fingerprint`]); `prefix_fps[i]` identifies the
    /// prefix `stages[0..=i]`. Computed — and address identities
    /// registered — only for cacheable plans that actually mark a cut
    /// (empty otherwise, so plans that never cache cost the session
    /// registry nothing).
    pub prefix_fps: Vec<u64>,
    /// Whether prefix fingerprints identify real computation: requires an
    /// identity-bearing `Source` root (co-group-rooted plans and stream
    /// sources lower with `cacheable: false`, and their cut points
    /// materialize without touching the cache).
    pub cacheable: bool,
    /// Per-stage adaptive execution hints derived from the feedback
    /// store at lowering time (parallel to the stage list; all `None`
    /// without an [`AdaptiveCtx`] or on a cold store).
    pub adapt: Vec<Option<StageAdapt>>,
    /// The adaptive section of the eventual plan report: whether the
    /// store was consulted and every decision taken. `None` when
    /// lowering ran without an [`AdaptiveCtx`].
    pub adaptation: Option<AdaptationReport>,
}

/// Lowering-time adaptive context: the session's feedback store plus the
/// thread count the static shard default derives from. Passing the same
/// context to [`lower_adaptive`] and [`describe_adaptive`] is what pins
/// `explain()` ≡ the executed plan — both derive hints through the same
/// pure helpers in [`crate::stats`] against the same store.
pub struct AdaptiveCtx<'a> {
    /// The session [`StatsStore`] (see [`crate::api::Runtime::stats`]).
    pub store: &'a StatsStore,
    /// Worker threads the executing config will run with.
    pub threads: usize,
}

/// Lower a logical stage list to a physical plan via the agent's
/// whole-plan pass. Plans are linear chains today, so "does this reduce
/// follow a reduce" is simply "is there any upstream reduce stage".
///
/// Fusion is all-or-nothing per element-wise chain (a half-fused chain
/// would still materialize), so one optimizer-off stage demotes its whole
/// contiguous run before the agent decides — keeping the decisions, the
/// plan report, and the agent's statistics faithful to what the executor
/// actually does under mixed per-stage modes. A chain feeding a
/// [`StageKind::Cache`] cut is demoted the same way: the cut *is* a
/// materialization point (that is what gets stored), so reporting those
/// ops as fused would be dishonest.
pub fn lower(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
) -> PhysicalPlan {
    lower_impl(stages, agent, registry, true, None)
}

/// [`lower`] with adaptive re-optimization: consult the session feedback
/// store for statistics recorded by earlier runs of the same structural
/// prefixes and derive per-stage execution hints plus the
/// [`AdaptationReport`] naming every decision. With `ctx: None` this *is*
/// the static [`lower`].
pub fn lower_adaptive(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
    ctx: Option<&AdaptiveCtx<'_>>,
) -> PhysicalPlan {
    lower_impl(stages, agent, registry, true, ctx)
}

fn lower_impl(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
    record: bool,
    ctx: Option<&AdaptiveCtx<'_>>,
) -> PhysicalPlan {
    // Mark every element-wise stage whose contiguous run contains an
    // optimizer-off stage, or whose run feeds a cache cut (the chain
    // materializes into the stored entry).
    let mut chain_off = vec![false; stages.len()];
    let mut i = 0;
    while i < stages.len() {
        if is_element_wise(stages[i].kind) {
            let start = i;
            let mut any_off = false;
            while i < stages.len() && is_element_wise(stages[i].kind) {
                any_off |= matches!(stages[i].optimize, OptimizeMode::Off);
                i += 1;
            }
            let feeds_cut = stages.get(i).is_some_and(|s| s.kind == StageKind::Cache);
            if any_off || feeds_cut {
                for flag in &mut chain_off[start..i] {
                    *flag = true;
                }
            }
        } else {
            i += 1;
        }
    }

    let mut shapes = Vec::with_capacity(stages.len());
    let mut seen_reduce = false;
    for (i, stage) in stages.iter().enumerate() {
        shapes.push(match stage.kind {
            StageKind::Source => StageShape::Source,
            StageKind::Map | StageKind::Filter | StageKind::FlatMap => StageShape::ElementWise {
                mode: if chain_off[i] {
                    OptimizeMode::Off
                } else {
                    stage.optimize
                },
            },
            // Keyed aggregation is a reduce-shaped barrier to the
            // whole-plan pass: it can fuse its upstream chain and stream
            // a reduce handoff exactly like `map_reduce`; whether its
            // *combining* rewrite fires is decided per stage by the
            // agent's declared channel at execution time (mirroring the
            // per-class inferred path).
            StageKind::MapReduce | StageKind::KeyedAggregate => {
                let shape = StageShape::Reduce {
                    mode: stage.optimize,
                    follows_reduce: seen_reduce,
                };
                seen_reduce = true;
                shape
            }
            // A co-group executes both inputs as sub-plans of its own, so
            // the outer plan never streams into it — but its *output* is
            // sharded like any reduce stage, so downstream stages may.
            StageKind::CoGroup => {
                seen_reduce = true;
                StageShape::Reduce {
                    mode: stage.optimize,
                    follows_reduce: false,
                }
            }
            // A cache cut holds sharded materialized data whichever way
            // it resolves, so downstream reduces may stream from it; the
            // cut itself needs no placement decision from the agent
            // (source-shaped: nothing to decide).
            StageKind::Cache => {
                seen_reduce = true;
                StageShape::Source
            }
        });
    }
    // Fingerprint plans that can and do cache, plus every adaptive
    // lowering: the feedback store shares the cache's fingerprint path,
    // so non-caching adaptive plans pay the hashing (and register their
    // address identities) too. Static, cut-less plans still skip it.
    let has_cut = stages.iter().any(|s| s.kind == StageKind::Cache);
    let cacheable = has_cut && fingerprint::cacheable(stages);
    let prefix_fps = if cacheable || !record || ctx.is_some() {
        // `!record` is the observational `describe()` pass, which shows
        // fingerprints even for cut-less plans.
        fingerprint::prefix_fingerprints(stages, registry)
    } else {
        Vec::new()
    };

    // Derive per-stage execution hints and the decision log from the
    // feedback store. Stage-level `Off` stages are never adapted — the
    // static opt-out must stay byte-for-byte reachable per stage too.
    let mut adapt: Vec<Option<StageAdapt>> = vec![None; stages.len()];
    let mut adaptation = None;
    if let Some(ctx) = ctx {
        let default_shards = shard_count(ctx.threads);
        let mut samples = 0u64;
        let mut decisions = Vec::new();
        let mut i = 0usize;
        while i < stages.len() {
            let stage = &stages[i];
            let off = matches!(stage.optimize, OptimizeMode::Off);
            match stage.kind {
                StageKind::Filter if !off => {
                    // A run of consecutive (non-Off) filters: measured
                    // selectivities, keyed by each predicate's original
                    // recorded position, pick the execution order.
                    let start = i;
                    while i < stages.len()
                        && stages[i].kind == StageKind::Filter
                        && !matches!(stages[i].optimize, OptimizeMode::Off)
                    {
                        i += 1;
                    }
                    let run: Vec<Option<stats::FilterStats>> = (start..i)
                        .map(|j| prefix_fps.get(j).and_then(|&fp| ctx.store.filter(fp)))
                        .collect();
                    for s in run.iter().flatten() {
                        samples = samples.max(s.samples);
                    }
                    if let Some(order) = stats::filter_order(&run) {
                        let selectivities = run.iter().map(|s| s.unwrap().selectivity()).collect();
                        decisions.push(AdaptiveDecision::FilterReorder {
                            first_stage: start,
                            order,
                            selectivities,
                        });
                    }
                }
                StageKind::MapReduce | StageKind::KeyedAggregate if !off => {
                    if let Some(flow) = prefix_fps.get(i).and_then(|&fp| ctx.store.flow(fp)) {
                        samples = samples.max(flow.samples);
                        if let Some(hints) = stats::derive_stage_adapt(&flow, default_shards) {
                            if let Some(to) = hints.shard_override {
                                decisions.push(AdaptiveDecision::ShardCount {
                                    stage: i,
                                    from: default_shards,
                                    to,
                                    keys: flow.last.keys,
                                });
                            }
                            if hints.prefer_list {
                                decisions.push(AdaptiveDecision::FlowSwitch {
                                    stage: i,
                                    emits: flow.last.emits,
                                    keys: flow.last.keys,
                                });
                            }
                            if let Some(hot) = hints.hot_key {
                                let skew = flow.last.skew.unwrap_or_default();
                                decisions.push(AdaptiveDecision::HotKeySplit {
                                    stage: i,
                                    hot_hash: hot,
                                    support: skew.hot_support,
                                    emits: skew.emits,
                                });
                            }
                            adapt[i] = Some(hints);
                        }
                    }
                    i += 1;
                }
                _ => i += 1,
            }
        }
        adaptation = Some(AdaptationReport {
            consulted: true,
            samples,
            decisions,
        });
    }

    let decisions = if record {
        agent.plan_with(&shapes, &adapt)
    } else {
        agent.plan_preview_with(&shapes, &adapt)
    };
    let fused_ops = decisions
        .iter()
        .filter(|d| matches!(d, StageDecision::Fuse))
        .count();
    let streamed_handoffs = decisions
        .iter()
        .filter(|d| matches!(d, StageDecision::StreamInput))
        .count();
    PhysicalPlan {
        decisions,
        fused_ops,
        streamed_handoffs,
        prefix_fps,
        cacheable,
        adapt,
        adaptation,
    }
}

fn kind_label(kind: StageKind) -> &'static str {
    match kind {
        StageKind::Source => "source",
        StageKind::Map => "map",
        StageKind::Filter => "filter",
        StageKind::FlatMap => "flat_map",
        StageKind::MapReduce => "map_reduce",
        StageKind::KeyedAggregate => "keyed_aggregate",
        StageKind::CoGroup => "co_group",
        StageKind::Cache => "cache",
    }
}

fn decision_label(d: &StageDecision) -> &'static str {
    match d {
        StageDecision::Input => "input",
        StageDecision::Fuse => "fuse",
        StageDecision::Materialize => "materialize",
        StageDecision::StreamInput => "stream-input",
        StageDecision::MaterializeInput => "materialize-input",
    }
}

/// Render a lowered plan for humans ([`Dataset::explain`]): stage kinds
/// and names, the whole-plan pass's decisions, prefix fingerprints, and
/// cache cut points. Uses the agent's non-recording preview, so calling
/// it leaves the optimizer statistics untouched.
///
/// [`Dataset::explain`]: crate::api::plan::Dataset::explain
pub(crate) fn describe(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
) -> String {
    describe_adaptive(stages, agent, registry, None)
}

/// [`describe`] with the adaptive context the real lowering would use, so
/// the rendered plan includes the same [`AdaptationReport`] the executed
/// plan will carry — preview ≡ plan by construction.
pub(crate) fn describe_adaptive(
    stages: &[StageInfo],
    agent: &OptimizerAgent,
    registry: &MaterializationCache,
    ctx: Option<&AdaptiveCtx<'_>>,
) -> String {
    use std::fmt::Write;
    let plan = lower_impl(stages, agent, registry, false, ctx);
    // `plan.cacheable` additionally requires a cut; for display we care
    // about whether the *root* is identifiable at all.
    let root_identified = fingerprint::cacheable(stages);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "plan: {} stage(s), prefix fingerprints {}",
        stages.len(),
        if root_identified {
            "active"
        } else {
            "inactive (unidentified source)"
        }
    );
    for (i, s) in stages.iter().enumerate() {
        let decision = plan
            .decisions
            .get(i)
            .map(decision_label)
            .unwrap_or("?");
        let fp = plan.prefix_fps.get(i).copied().unwrap_or(0);
        if s.kind == StageKind::Cache {
            // Live cuts also show where the prefix currently resides in
            // the two-tier store (hot / in-flight / spilled / absent).
            let status = if root_identified {
                format!(", {}", registry.residency(Fingerprint(fp)))
            } else {
                " (inactive)".to_string()
            };
            let _ = writeln!(
                out,
                "  [{i}] cache            — cut point, prefix fp {}{}",
                Fingerprint(fp),
                status,
            );
        } else {
            let _ = writeln!(
                out,
                "  [{i}] {:<16} {:<24} {:<12} {:?}  fp {}",
                kind_label(s.kind),
                s.name,
                decision,
                s.optimize,
                Fingerprint(fp),
            );
        }
    }
    let _ = writeln!(
        out,
        "fused element-wise ops: {}; streamed handoffs: {}",
        plan.fused_ops, plan.streamed_handoffs
    );
    match &plan.adaptation {
        None => {
            let _ = writeln!(out, "adaptive: off (static plan)");
        }
        Some(a) if a.decisions.is_empty() => {
            let _ = writeln!(
                out,
                "adaptive: feedback store consulted ({} sample(s)); no adaptations",
                a.samples
            );
        }
        Some(a) => {
            let _ = writeln!(
                out,
                "adaptive: feedback store consulted ({} sample(s)); {} decision(s):",
                a.samples,
                a.decisions.len()
            );
            for d in &a.decisions {
                let _ = writeln!(out, "  - {d}");
            }
        }
    }
    out
}

/// Execution context for one plan run (one `collect` call): the session
/// resources every stage shares, the lowered plan, and the running
/// measurements.
pub struct PlanExec<'rt> {
    pub(crate) pool: &'rt WorkerPool,
    pub(crate) agent: &'rt OptimizerAgent,
    plan: PhysicalPlan,
    stage_metrics: Vec<FlowMetrics>,
    materialized: u64,
    /// Rewrite counts absorbed from sub-plans (two-input stages execute
    /// each input as its own lowered plan and merge the accounting here).
    absorbed_fused: usize,
    absorbed_streamed: usize,
    /// Cache activity since the last executed stage, attached to the next
    /// stage's metrics (the stage that consumed the resolved input).
    pending_cache: Option<CacheActivity>,
    /// Plan-total cache activity (the [`PlanReport::cache`] field).
    cache_total: CacheActivity,
    /// The adaptive section of the eventual report, taken off the plan at
    /// construction (the report owns it; the plan keeps only the hints).
    adaptation: Option<AdaptationReport>,
}

impl<'rt> PlanExec<'rt> {
    pub(crate) fn new(
        pool: &'rt WorkerPool,
        agent: &'rt OptimizerAgent,
        mut plan: PhysicalPlan,
    ) -> Self {
        let adaptation = plan.adaptation.take();
        PlanExec {
            pool,
            agent,
            plan,
            stage_metrics: Vec::new(),
            materialized: 0,
            absorbed_fused: 0,
            absorbed_streamed: 0,
            pending_cache: None,
            cache_total: CacheActivity::default(),
            adaptation,
        }
    }

    /// True when every element-wise stage in `range` fuses into its
    /// consumer (vacuously true for an empty chain — a direct handoff).
    pub(crate) fn chain_fused(&self, range: &Range<usize>) -> bool {
        range
            .clone()
            .all(|i| matches!(self.plan.decisions.get(i), Some(StageDecision::Fuse)))
    }

    /// True when the reduce stage at logical index `index` consumes its
    /// upstream's shard outputs as a stream.
    pub(crate) fn stream_input(&self, index: usize) -> bool {
        matches!(
            self.plan.decisions.get(index),
            Some(StageDecision::StreamInput)
        )
    }

    /// The prefix fingerprint a cache cut at logical index `index`
    /// resolves against, or `None` when the plan has no identified source
    /// (the cut then materializes without touching the cache).
    pub(crate) fn cut_fingerprint(&self, index: usize) -> Option<Fingerprint> {
        if self.plan.cacheable {
            self.plan.prefix_fps.get(index).map(|&h| Fingerprint(h))
        } else {
            None
        }
    }

    /// The adaptive execution hints lowered for the stage at logical
    /// index `index`, if any.
    pub(crate) fn adaptive_for(&self, index: usize) -> Option<&StageAdapt> {
        self.plan.adapt.get(index).and_then(|a| a.as_ref())
    }

    /// The prefix fingerprint identifying `stages[0..=index]` for the
    /// feedback store, when this lowering computed fingerprints at all
    /// (adaptive lowerings always do).
    pub(crate) fn stage_fp(&self, index: usize) -> Option<u64> {
        self.plan.prefix_fps.get(index).copied()
    }

    /// Record cache activity from resolving a cut point: totalled into
    /// the plan report, and attached to the next executed stage's metrics
    /// (the stage that consumed the resolved input).
    pub(crate) fn note_cache(&mut self, activity: CacheActivity) {
        self.cache_total.add(&activity);
        self.pending_cache
            .get_or_insert_with(CacheActivity::default)
            .add(&activity);
    }

    /// Record `n` elements materialized into a plan-level intermediate.
    pub(crate) fn note_materialized(&mut self, n: u64) {
        self.materialized += n;
    }

    /// Record one executed reduce stage's metrics.
    pub(crate) fn push_metrics(&mut self, mut metrics: FlowMetrics) {
        metrics.cache = self.pending_cache.take();
        self.stage_metrics.push(metrics);
    }

    /// Merge a sub-plan's report into this execution (two-input stages:
    /// each co-group input runs as its own lowered plan). Stage metrics
    /// append in execution order; rewrite and materialization accounting
    /// add up, so the outer [`PlanReport`] covers the whole tree.
    pub(crate) fn absorb(&mut self, report: PlanReport) {
        self.absorbed_fused += report.fused_ops;
        self.absorbed_streamed += report.streamed_handoffs;
        self.materialized += report.materialized_pairs;
        self.cache_total.add(&report.cache);
        self.stage_metrics.extend(report.stage_metrics);
        if let Some(sub) = report.adaptation {
            let a = self.adaptation.get_or_insert_with(AdaptationReport::default);
            a.consulted |= sub.consulted;
            a.samples = a.samples.max(sub.samples);
            a.decisions.extend(sub.decisions);
        }
    }

    pub(crate) fn into_report(self) -> PlanReport {
        PlanReport {
            stage_metrics: self.stage_metrics,
            fused_ops: self.plan.fused_ops + self.absorbed_fused,
            streamed_handoffs: self.plan.streamed_handoffs + self.absorbed_streamed,
            materialized_pairs: self.materialized,
            cache: self.cache_total,
            stream: None,
            govern: None,
            adaptation: self.adaptation,
            trace: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::config::OptimizeMode;

    fn info(kind: StageKind, mode: OptimizeMode) -> StageInfo {
        StageInfo {
            kind,
            name: "t".into(),
            optimize: mode,
            token: Some(crate::api::plan::StageToken::Stable(1)),
        }
    }

    fn registry() -> MaterializationCache {
        MaterializationCache::new()
    }

    #[test]
    fn lower_marks_fusion_and_streaming() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Filter, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        assert_eq!(plan.fused_ops, 1);
        assert_eq!(plan.streamed_handoffs, 1);
        assert_eq!(plan.decisions[1], StageDecision::MaterializeInput);
        assert_eq!(plan.decisions[3], StageDecision::StreamInput);
    }

    #[test]
    fn lower_off_mode_is_fully_materialized() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
            info(StageKind::Map, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
        ];
        let plan = lower(&stages, &agent, &registry());
        assert_eq!(plan.fused_ops, 0);
        assert_eq!(plan.streamed_handoffs, 0);
    }

    #[test]
    fn mixed_mode_chain_is_demoted_whole() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Map, OptimizeMode::Auto),
            info(StageKind::Filter, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        // One Off stage demotes the whole chain…
        assert_eq!(plan.decisions[2], StageDecision::Materialize);
        assert_eq!(plan.decisions[3], StageDecision::Materialize);
        assert_eq!(plan.fused_ops, 0);
        // …but the Auto reduce still streams its handoff: the chain
        // stages, not the handoff, are what the Off stage governs.
        assert_eq!(plan.decisions[4], StageDecision::StreamInput);
        assert_eq!(plan.streamed_handoffs, 1);
    }

    #[test]
    fn keyed_stages_lower_like_reduces_and_cogroups_never_stream_in() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::CoGroup, OptimizeMode::Auto),
            info(StageKind::FlatMap, OptimizeMode::Auto),
            info(StageKind::KeyedAggregate, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        // The co-group materializes its own inputs (sub-plans), but its
        // sharded output streams into the downstream keyed aggregate.
        assert_eq!(plan.decisions[0], StageDecision::MaterializeInput);
        assert_eq!(plan.decisions[1], StageDecision::Fuse);
        assert_eq!(plan.decisions[2], StageDecision::StreamInput);
        assert_eq!((plan.fused_ops, plan.streamed_handoffs), (1, 1));
    }

    #[test]
    fn cache_cut_streams_downstream_and_demotes_its_chain() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::Map, OptimizeMode::Auto),
            info(StageKind::Cache, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let plan = lower(&stages, &agent, &registry());
        // The chain feeding the cut materializes (into the entry)…
        assert_eq!(plan.decisions[1], StageDecision::Materialize);
        assert_eq!(plan.fused_ops, 0);
        // …and the cut's sharded output streams into the downstream
        // reduce like any barrier's would.
        assert_eq!(plan.decisions[3], StageDecision::StreamInput);
        assert!(plan.cacheable);
        assert_eq!(plan.prefix_fps.len(), 4);
    }

    #[test]
    fn unidentified_sources_lower_uncacheable() {
        let agent = OptimizerAgent::new();
        let mut stages = vec![
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::Cache, OptimizeMode::Auto),
        ];
        stages[0].token = None; // stream source
        assert!(!lower(&stages, &agent, &registry()).cacheable);
        let cogroup = [info(StageKind::CoGroup, OptimizeMode::Auto)];
        assert!(!lower(&cogroup, &agent, &registry()).cacheable);
    }

    #[test]
    fn describe_renders_decisions_and_cuts_without_stats() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
            info(StageKind::Cache, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        let text = describe(&stages, &agent, &registry());
        assert!(text.contains("cache"), "{text}");
        assert!(text.contains("cut point"), "{text}");
        assert!(text.contains("stream-input"), "{text}");
        assert!(text.contains("fp "), "{text}");
        assert_eq!(agent.stats().plans, 0, "describe must not record a plan pass");
    }

    #[test]
    fn adaptive_lowering_consults_store_and_derives_hints() {
        let agent = OptimizerAgent::new();
        let registry = registry();
        let store = StatsStore::new();
        let mut stages = vec![
            info(StageKind::Source, OptimizeMode::Auto),
            info(StageKind::MapReduce, OptimizeMode::Auto),
        ];
        stages[1].token = Some(crate::api::plan::StageToken::Stable(2));
        let ctx = AdaptiveCtx {
            store: &store,
            threads: 8,
        };
        // Cold store: consulted, no decisions, no hints.
        let cold = lower_adaptive(&stages, &agent, &registry, Some(&ctx));
        assert_eq!(cold.prefix_fps.len(), 2, "adaptive lowering fingerprints");
        let report = cold.adaptation.as_ref().unwrap();
        assert!(report.consulted);
        assert!(report.decisions.is_empty());
        assert!(cold.adapt.iter().all(|a| a.is_none()));
        // Record a low-cardinality run and lower again: the shard count
        // shrinks and the decision is named.
        store.record_flow(
            cold.prefix_fps[1],
            stats::FlowObservation {
                emits: 100_000,
                keys: 5,
                results: 5,
                combine_flow: true,
                declared: false,
                ..stats::FlowObservation::default()
            },
        );
        let warm = lower_adaptive(&stages, &agent, &registry, Some(&ctx));
        let hints = warm.adapt[1].as_ref().expect("hints derived");
        assert_eq!(hints.shard_override, Some(16));
        let report = warm.adaptation.as_ref().unwrap();
        assert_eq!(report.samples, 1);
        assert!(matches!(
            report.decisions.as_slice(),
            [AdaptiveDecision::ShardCount {
                stage: 1,
                from: 128,
                to: 16,
                keys: 5,
            }]
        ));
        assert!(store.consults() > 0, "warm lowering hit the store");
        // Static lowering of the same stages ignores the store entirely.
        assert!(lower(&stages, &agent, &registry).adaptation.is_none());
        // The preview path renders the same decision.
        let text = describe_adaptive(&stages, &agent, &registry, Some(&ctx));
        assert!(text.contains("shard count @ stage 1: 128 -> 16"), "{text}");
    }

    #[test]
    fn exec_chain_fused_is_vacuous_on_empty_ranges() {
        let agent = OptimizerAgent::new();
        let stages = [
            info(StageKind::Source, OptimizeMode::Off),
            info(StageKind::MapReduce, OptimizeMode::Off),
        ];
        let plan = lower(&stages, &agent, &registry());
        let pool = WorkerPool::new(1);
        let exec = PlanExec::new(&pool, &agent, plan);
        assert!(exec.chain_fused(&(1..1)), "empty chain is a direct handoff");
        assert!(!exec.stream_input(1));
    }
}
