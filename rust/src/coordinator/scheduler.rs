//! A work-stealing task pool — the ForkJoinPool stand-in (paper §2.4: "The
//! ForkJoinPool class ... provide\[s\] a clean, off-the-shelf scheduler
//! focusing on lightweight tasks executing on worker threads accessed from
//! a work-stealing queue").
//!
//! Shape: a run submits a flat batch of tasks; each worker owns a deque
//! seeded round-robin; workers pop their own deque LIFO (cache-warm) and
//! steal FIFO from victims when empty (cold end — classic Chase-Lev
//! discipline, implemented with mutexed deques since task granularity here
//! is a whole input chunk, thousands of map calls, so queue ops are far off
//! the critical path).
//!
//! Workers are OS threads scoped to the run (`std::thread::scope`), so
//! tasks may borrow from the caller's stack — which is exactly how the
//! pipeline hands collectors and mappers to workers without `Arc`ing the
//! world.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Counters exposed for tests and the perf harness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub executed: usize,
    pub steals: usize,
}

/// A batch-mode work-stealing pool.
#[derive(Debug)]
pub struct TaskPool {
    threads: usize,
}

impl TaskPool {
    /// A pool with `threads` workers (≥ 1).
    pub fn new(threads: usize) -> Self {
        TaskPool {
            threads: threads.max(1),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion; returns scheduling stats.
    ///
    /// Tasks are `FnOnce` closures that may borrow non-`'static` state
    /// (scoped threads). Panics in tasks propagate after all workers join.
    pub fn run<'scope, F>(&self, tasks: Vec<F>) -> PoolStats
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        if tasks.is_empty() {
            return PoolStats::default();
        }
        let n_workers = self.threads.min(tasks.len()).max(1);
        // Seed the deques round-robin.
        let queues: Vec<Mutex<VecDeque<F>>> = (0..n_workers)
            .map(|_| Mutex::new(VecDeque::new()))
            .collect();
        for (i, t) in tasks.into_iter().enumerate() {
            queues[i % n_workers].lock().unwrap().push_back(t);
        }
        let executed = AtomicUsize::new(0);
        let steals = AtomicUsize::new(0);

        std::thread::scope(|s| {
            for wid in 0..n_workers {
                let queues = &queues;
                let executed = &executed;
                let steals = &steals;
                s.spawn(move || {
                    loop {
                        // Own queue first: LIFO end (most recently pushed →
                        // warm caches for recursive spawn patterns).
                        let task = queues[wid].lock().unwrap().pop_back();
                        if let Some(t) = task {
                            t(wid);
                            executed.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        // Steal: scan victims from wid+1, take the FIFO end.
                        let mut stolen = None;
                        for off in 1..n_workers {
                            let victim = (wid + off) % n_workers;
                            if let Some(t) = queues[victim].lock().unwrap().pop_front() {
                                stolen = Some(t);
                                break;
                            }
                        }
                        match stolen {
                            Some(t) => {
                                steals.fetch_add(1, Ordering::Relaxed);
                                t(wid);
                                executed.fetch_add(1, Ordering::Relaxed);
                            }
                            // All queues empty: batch mode → done.
                            None => break,
                        }
                    }
                });
            }
        });

        PoolStats {
            executed: executed.load(Ordering::Relaxed),
            steals: steals.load(Ordering::Relaxed),
        }
    }

    /// Convenience: run the same closure over every index in `0..n` with
    /// automatic chunking — the map-phase shape.
    pub fn run_indexed<'scope, F>(&self, n: usize, f: F) -> PoolStats
    where
        F: Fn(usize, usize) + Send + Sync + 'scope,
    {
        // One task per chunk; ~4 chunks per worker balances stealing
        // opportunity against queue traffic (Phoenix uses a similar
        // heuristic for its task granularity).
        let chunks = super::splitter::split_indices(n, self.threads * 4);
        let f = &f;
        self.run(
            chunks
                .into_iter()
                .map(|range| {
                    move |wid: usize| {
                        for i in range {
                            f(wid, i);
                        }
                    }
                })
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------
// Persistent worker pool — the runtime-session scheduler
// ---------------------------------------------------------------------

/// A task queued on a persistent worker (lifetime-erased; see the safety
/// argument on [`WorkerPool::run`]).
type Job = Box<dyn FnOnce(usize) + Send + 'static>;

struct PoolState {
    /// One deque per spawned worker, seeded round-robin per batch.
    queues: Vec<VecDeque<Job>>,
    /// Workers allowed to execute the current batch (`wid < active`);
    /// the rest keep sleeping, so a session pool sized for the machine can
    /// still run a 1-thread ablation job.
    active: usize,
    /// Submitted-but-unfinished tasks of the current batch.
    pending: usize,
    executed: usize,
    steals: usize,
    panicked: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers sleep here between batches.
    work_cv: Condvar,
    /// The submitting thread sleeps here until `pending == 0`.
    done_cv: Condvar,
}

impl PoolShared {
    /// Lock the state, shrugging off poisoning: a panicking task is caught
    /// before it can poison anything, and batch completion must survive
    /// sibling panics so the borrow-based safety argument holds.
    fn lock(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A **persistent** work-stealing pool: worker OS threads are spawned once
/// per session and reused by every job, unlike [`TaskPool`] which scopes a
/// fresh set of threads to each `run` call.
///
/// This is the pool a [`crate::api::Runtime`] owns. A k-means pipeline
/// running 5 Lloyd iterations pays thread-spawn cost once, not 10× (map +
/// reduce per iteration); [`WorkerPool::spawned_threads`] makes the reuse
/// observable to tests.
///
/// Scheduling discipline matches [`TaskPool`]: per-worker deques seeded
/// round-robin, LIFO self-pop, FIFO steal from victims. Queue operations
/// sit under one pool mutex — task granularity is a whole input chunk, so
/// queue traffic is far off the critical path, and a single mutex keeps
/// the sleep/wake protocol (two condvars) easy to reason about.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Serializes batches: one job phase owns the workers at a time.
    batch: Mutex<()>,
    spawned: AtomicUsize,
}

impl WorkerPool {
    /// A session pool with `threads` workers spawned up front (≥ 1). The
    /// pool grows on demand if a later job asks for more workers.
    pub fn new(threads: usize) -> Self {
        let pool = WorkerPool {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    queues: Vec::new(),
                    active: 0,
                    pending: 0,
                    executed: 0,
                    steals: 0,
                    panicked: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
            batch: Mutex::new(()),
            spawned: AtomicUsize::new(0),
        };
        pool.ensure_workers(threads.max(1));
        pool
    }

    /// Total worker threads ever spawned by this pool — the session-reuse
    /// observable: two jobs on one pool leave this unchanged.
    pub fn spawned_threads(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Spawn workers until at least `n` exist.
    fn ensure_workers(&self, n: usize) {
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        let current = self.spawned.load(Ordering::SeqCst);
        if current >= n {
            return;
        }
        {
            let mut state = self.shared.lock();
            state.queues.resize_with(n, VecDeque::new);
        }
        for wid in current..n {
            let shared = Arc::clone(&self.shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mr4r-worker-{wid}"))
                    .spawn(move || worker_loop(&shared, wid))
                    .expect("spawn pool worker"),
            );
        }
        self.spawned.store(n, Ordering::SeqCst);
    }

    /// Run every task to completion on at most `workers` of the pool's
    /// threads; returns scheduling stats. Panics (after the whole batch
    /// has drained) if any task panicked.
    ///
    /// Tasks may borrow non-`'static` state from the caller's stack, like
    /// [`TaskPool::run`]. Safety: each task is lifetime-erased to be
    /// queued on persistent threads, and this function does not return
    /// until every queued task has finished executing (the `pending`
    /// count reaches zero under the pool mutex), so no borrow outlives
    /// the frame that owns it. Do not call `run` from inside a pool task:
    /// batches are serialized and the nested call would deadlock.
    pub fn run<'scope, F>(&self, workers: usize, tasks: Vec<F>) -> PoolStats
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        if tasks.is_empty() {
            return PoolStats::default();
        }
        let workers = workers.max(1).min(tasks.len());
        self.ensure_workers(workers);
        let _batch = self.batch.lock().unwrap_or_else(|e| e.into_inner());

        {
            let mut state = self.shared.lock();
            state.active = workers;
            state.pending = tasks.len();
            state.executed = 0;
            state.steals = 0;
            state.panicked = 0;
            for (i, t) in tasks.into_iter().enumerate() {
                let job: Box<dyn FnOnce(usize) + Send + 'scope> = Box::new(t);
                // SAFETY: see above — the wait loop below keeps every
                // borrow in `job` alive until the job has run.
                let job: Job = unsafe { std::mem::transmute(job) };
                state.queues[i % workers].push_back(job);
            }
        }
        self.shared.work_cv.notify_all();

        let mut state = self.shared.lock();
        while state.pending > 0 {
            state = self
                .shared
                .done_cv
                .wait(state)
                .unwrap_or_else(|e| e.into_inner());
        }
        let stats = PoolStats {
            executed: state.executed,
            steals: state.steals,
        };
        let panicked = state.panicked;
        state.active = 0;
        drop(state);
        drop(_batch);
        if panicked > 0 {
            panic!("{panicked} worker-pool task(s) panicked");
        }
        stats
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.lock().shutdown = true;
        self.shared.work_cv.notify_all();
        let handles = std::mem::take(
            &mut *self.handles.lock().unwrap_or_else(|e| e.into_inner()),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, wid: usize) {
    let mut state = shared.lock();
    loop {
        if state.shutdown {
            return;
        }
        let mut task = None;
        let mut stolen = false;
        if wid < state.active {
            // Own queue first: LIFO end (cache-warm).
            task = state.queues[wid].pop_back();
            if task.is_none() {
                // Steal: scan victims from wid+1, take the FIFO end.
                let n = state.active;
                for off in 1..n {
                    let victim = (wid + off) % n;
                    if let Some(t) = state.queues[victim].pop_front() {
                        task = Some(t);
                        stolen = true;
                        break;
                    }
                }
            }
        }
        match task {
            Some(t) => {
                if stolen {
                    state.steals += 1;
                }
                drop(state);
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t(wid)))
                    .is_ok();
                state = shared.lock();
                state.executed += 1;
                if !ok {
                    state.panicked += 1;
                }
                state.pending -= 1;
                if state.pending == 0 {
                    shared.done_cv.notify_all();
                }
            }
            None => {
                state = shared
                    .work_cv
                    .wait(state)
                    .unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn executes_every_task_exactly_once() {
        let pool = TaskPool::new(4);
        let n = 1000;
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..n)
            .map(|_| {
                let c = &counter;
                move |_wid: usize| {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        let stats = pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), n);
        assert_eq!(stats.executed, n);
    }

    #[test]
    fn empty_batch_is_fine() {
        let pool = TaskPool::new(4);
        let stats = pool.run(Vec::<fn(usize)>::new());
        assert_eq!(stats.executed, 0);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = TaskPool::new(1);
        let acc = AtomicU64::new(0);
        let tasks: Vec<_> = (0..64u64)
            .map(|i| {
                let acc = &acc;
                move |_w: usize| {
                    acc.fetch_add(i, Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(tasks);
        assert_eq!(acc.load(Ordering::Relaxed), 63 * 64 / 2);
    }

    #[test]
    fn imbalanced_tasks_get_stolen() {
        // A long task placed at the LIFO end of worker 0's queue: worker 0
        // pops it first and blocks; its remaining short tasks can only be
        // finished by worker 1 stealing them.
        let pool = TaskPool::new(2);
        let done = AtomicUsize::new(0);
        let done_ref = &done;
        let n_short = 400;
        let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
        for _ in 0..n_short {
            tasks.push(Box::new(move |_w| {
                std::thread::sleep(std::time::Duration::from_micros(50));
                done_ref.fetch_add(1, Ordering::Relaxed);
            }));
        }
        // Index 400 % 2 == 0 → back of worker 0's deque → popped first.
        tasks.push(Box::new(move |_w| {
            std::thread::sleep(std::time::Duration::from_millis(60));
            done_ref.fetch_add(1, Ordering::Relaxed);
        }));
        let stats = pool.run(tasks);
        assert_eq!(done.load(Ordering::Relaxed), n_short + 1);
        assert!(stats.steals > 0, "expected steals on imbalanced load");
    }

    #[test]
    fn run_indexed_covers_range() {
        let pool = TaskPool::new(3);
        let n = 997; // prime → uneven chunks
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run_indexed(n, |_wid, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn tasks_can_borrow_stack_state() {
        let pool = TaskPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        pool.run_indexed(data.len(), |_w, i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn worker_ids_are_in_range() {
        let pool = TaskPool::new(4);
        let bad = AtomicUsize::new(0);
        pool.run_indexed(200, |wid, _i| {
            if wid >= 4 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
    }

    // ---- WorkerPool (persistent session pool) ----

    fn counting_tasks(n: usize, counter: &AtomicUsize) -> Vec<impl FnOnce(usize) + Send + '_> {
        (0..n)
            .map(|_| {
                move |_wid: usize| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect()
    }

    #[test]
    fn worker_pool_executes_every_task() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        let stats = pool.run(4, counting_tasks(1000, &counter));
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(stats.executed, 1000);
    }

    #[test]
    fn worker_pool_reuses_threads_across_batches() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.spawned_threads(), 3);
        let counter = AtomicUsize::new(0);
        for _ in 0..5 {
            pool.run(3, counting_tasks(50, &counter));
        }
        assert_eq!(counter.load(Ordering::Relaxed), 250);
        assert_eq!(pool.spawned_threads(), 3, "no respawn across batches");
    }

    #[test]
    fn worker_pool_grows_on_demand() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.spawned_threads(), 1);
        let counter = AtomicUsize::new(0);
        pool.run(4, counting_tasks(100, &counter));
        assert_eq!(pool.spawned_threads(), 4);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn worker_pool_respects_batch_worker_limit() {
        let pool = WorkerPool::new(4);
        let seen = Mutex::new(std::collections::HashSet::new());
        let tasks: Vec<_> = (0..200)
            .map(|_| {
                let seen = &seen;
                move |wid: usize| {
                    seen.lock().unwrap().insert(wid);
                }
            })
            .collect();
        pool.run(2, tasks);
        assert!(seen.lock().unwrap().iter().all(|&w| w < 2));
    }

    #[test]
    fn worker_pool_tasks_borrow_stack_state() {
        let pool = WorkerPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        let tasks: Vec<_> = (0..data.len())
            .map(|i| {
                let data = &data;
                let sum = &sum;
                move |_wid: usize| {
                    sum.fetch_add(data[i], Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(2, tasks);
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn worker_pool_steals_imbalanced_load() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let done_ref = &done;
        let n_short = 400;
        let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
        for _ in 0..n_short {
            tasks.push(Box::new(move |_w| {
                std::thread::sleep(std::time::Duration::from_micros(50));
                done_ref.fetch_add(1, Ordering::Relaxed);
            }));
        }
        // Index 400 % 2 == 0 → back of worker 0's deque → popped first.
        tasks.push(Box::new(move |_w| {
            std::thread::sleep(std::time::Duration::from_millis(60));
            done_ref.fetch_add(1, Ordering::Relaxed);
        }));
        let stats = pool.run(2, tasks);
        assert_eq!(done.load(Ordering::Relaxed), n_short + 1);
        assert!(stats.steals > 0, "expected steals on imbalanced load");
    }

    #[test]
    fn worker_pool_empty_batch_is_fine() {
        let pool = WorkerPool::new(2);
        let stats = pool.run(2, Vec::<fn(usize)>::new());
        assert_eq!(stats.executed, 0);
    }

    #[test]
    fn worker_pool_propagates_task_panics_after_drain() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
            tasks.push(Box::new(|_w| panic!("boom")));
            for _ in 0..50 {
                tasks.push(Box::new(|_w| {
                    done.fetch_add(1, Ordering::Relaxed);
                }));
            }
            pool.run(2, tasks);
        }));
        assert!(result.is_err(), "task panic must propagate");
        assert_eq!(done.load(Ordering::Relaxed), 50, "siblings still run");
        // The pool survives for the next batch.
        let counter = AtomicUsize::new(0);
        pool.run(2, counting_tasks(10, &counter));
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }
}
