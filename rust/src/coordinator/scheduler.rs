//! A work-stealing task pool — the ForkJoinPool stand-in (paper §2.4: "The
//! ForkJoinPool class ... provide\[s\] a clean, off-the-shelf scheduler
//! focusing on lightweight tasks executing on worker threads accessed from
//! a work-stealing queue").
//!
//! Shape: a run submits a flat batch of tasks; each worker owns a deque
//! seeded round-robin; workers pop their own deque LIFO (cache-warm) and
//! steal FIFO from victims when empty (cold end — classic Chase-Lev
//! discipline, implemented with mutexed deques since task granularity here
//! is a whole input chunk, thousands of map calls, so queue ops are far off
//! the critical path).
//!
//! Workers are OS threads scoped to the run (`std::thread::scope`), so
//! tasks may borrow from the caller's stack — which is exactly how the
//! pipeline hands collectors and mappers to workers without `Arc`ing the
//! world.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Counters exposed for tests and the perf harness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub executed: usize,
    pub steals: usize,
}

/// A batch-mode work-stealing pool.
#[derive(Debug)]
pub struct TaskPool {
    threads: usize,
}

impl TaskPool {
    /// A pool with `threads` workers (≥ 1).
    pub fn new(threads: usize) -> Self {
        TaskPool {
            threads: threads.max(1),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion; returns scheduling stats.
    ///
    /// Tasks are `FnOnce` closures that may borrow non-`'static` state
    /// (scoped threads). Panics in tasks propagate after all workers join.
    pub fn run<'scope, F>(&self, tasks: Vec<F>) -> PoolStats
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        if tasks.is_empty() {
            return PoolStats::default();
        }
        let n_workers = self.threads.min(tasks.len()).max(1);
        // Seed the deques round-robin.
        let queues: Vec<Mutex<VecDeque<F>>> = (0..n_workers)
            .map(|_| Mutex::new(VecDeque::new()))
            .collect();
        for (i, t) in tasks.into_iter().enumerate() {
            queues[i % n_workers].lock().unwrap().push_back(t);
        }
        let executed = AtomicUsize::new(0);
        let steals = AtomicUsize::new(0);

        std::thread::scope(|s| {
            for wid in 0..n_workers {
                let queues = &queues;
                let executed = &executed;
                let steals = &steals;
                s.spawn(move || {
                    loop {
                        // Own queue first: LIFO end (most recently pushed →
                        // warm caches for recursive spawn patterns).
                        let task = queues[wid].lock().unwrap().pop_back();
                        if let Some(t) = task {
                            t(wid);
                            executed.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        // Steal: scan victims from wid+1, take the FIFO end.
                        let mut stolen = None;
                        for off in 1..n_workers {
                            let victim = (wid + off) % n_workers;
                            if let Some(t) = queues[victim].lock().unwrap().pop_front() {
                                stolen = Some(t);
                                break;
                            }
                        }
                        match stolen {
                            Some(t) => {
                                steals.fetch_add(1, Ordering::Relaxed);
                                t(wid);
                                executed.fetch_add(1, Ordering::Relaxed);
                            }
                            // All queues empty: batch mode → done.
                            None => break,
                        }
                    }
                });
            }
        });

        PoolStats {
            executed: executed.load(Ordering::Relaxed),
            steals: steals.load(Ordering::Relaxed),
        }
    }

    /// Convenience: run the same closure over every index in `0..n` with
    /// automatic chunking — the map-phase shape.
    pub fn run_indexed<'scope, F>(&self, n: usize, f: F) -> PoolStats
    where
        F: Fn(usize, usize) + Send + Sync + 'scope,
    {
        // One task per chunk; ~4 chunks per worker balances stealing
        // opportunity against queue traffic (Phoenix uses a similar
        // heuristic for its task granularity).
        let chunks = super::splitter::split_indices(n, self.threads * 4);
        let f = &f;
        self.run(
            chunks
                .into_iter()
                .map(|range| {
                    move |wid: usize| {
                        for i in range {
                            f(wid, i);
                        }
                    }
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn executes_every_task_exactly_once() {
        let pool = TaskPool::new(4);
        let n = 1000;
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..n)
            .map(|_| {
                let c = &counter;
                move |_wid: usize| {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        let stats = pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), n);
        assert_eq!(stats.executed, n);
    }

    #[test]
    fn empty_batch_is_fine() {
        let pool = TaskPool::new(4);
        let stats = pool.run(Vec::<fn(usize)>::new());
        assert_eq!(stats.executed, 0);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = TaskPool::new(1);
        let acc = AtomicU64::new(0);
        let tasks: Vec<_> = (0..64u64)
            .map(|i| {
                let acc = &acc;
                move |_w: usize| {
                    acc.fetch_add(i, Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(tasks);
        assert_eq!(acc.load(Ordering::Relaxed), 63 * 64 / 2);
    }

    #[test]
    fn imbalanced_tasks_get_stolen() {
        // A long task placed at the LIFO end of worker 0's queue: worker 0
        // pops it first and blocks; its remaining short tasks can only be
        // finished by worker 1 stealing them.
        let pool = TaskPool::new(2);
        let done = AtomicUsize::new(0);
        let done_ref = &done;
        let n_short = 400;
        let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
        for _ in 0..n_short {
            tasks.push(Box::new(move |_w| {
                std::thread::sleep(std::time::Duration::from_micros(50));
                done_ref.fetch_add(1, Ordering::Relaxed);
            }));
        }
        // Index 400 % 2 == 0 → back of worker 0's deque → popped first.
        tasks.push(Box::new(move |_w| {
            std::thread::sleep(std::time::Duration::from_millis(60));
            done_ref.fetch_add(1, Ordering::Relaxed);
        }));
        let stats = pool.run(tasks);
        assert_eq!(done.load(Ordering::Relaxed), n_short + 1);
        assert!(stats.steals > 0, "expected steals on imbalanced load");
    }

    #[test]
    fn run_indexed_covers_range() {
        let pool = TaskPool::new(3);
        let n = 997; // prime → uneven chunks
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run_indexed(n, |_wid, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn tasks_can_borrow_stack_state() {
        let pool = TaskPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        pool.run_indexed(data.len(), |_w, i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn worker_ids_are_in_range() {
        let pool = TaskPool::new(4);
        let bad = AtomicUsize::new(0);
        pool.run_indexed(200, |wid, _i| {
            if wid >= 4 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
    }
}
