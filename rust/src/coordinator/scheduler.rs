//! Work-stealing task pools — the ForkJoinPool stand-in (paper §2.4: "The
//! ForkJoinPool class ... provide\[s\] a clean, off-the-shelf scheduler
//! focusing on lightweight tasks executing on worker threads accessed from
//! a work-stealing queue").
//!
//! Two pools live here:
//!
//! * [`TaskPool`] — the batch-scoped pool (threads spawned per `run`,
//!   `std::thread::scope`), kept for the transient legacy path.
//! * [`WorkerPool`] — the persistent session pool a
//!   [`crate::api::Runtime`] owns. Since the multi-tenant redesign it is a
//!   **tagged-batch** scheduler: every submission is a [`Submission`]
//!   with its own deques and counters, and idle workers pick work
//!   **round-robin across the active submissions** (work-stealing stays
//!   *inside* a submission). Concurrent jobs from different driver
//!   threads therefore interleave at task granularity — a 10 ms
//!   interactive plan is not head-of-line blocked behind a 10 s analytics
//!   plan — and a panicking task fails only its own batch.
//!
//! Scheduling discipline inside a submission matches the classic
//! Chase-Lev shape: per-worker deques seeded round-robin, LIFO self-pop
//! (cache-warm), FIFO steal from victims (cold end). Queue operations sit
//! under one pool mutex — task granularity is a whole input chunk,
//! thousands of map calls, so queue traffic is far off the critical path,
//! and a single mutex keeps the sleep/wake protocol easy to reason about.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use crate::trace::metrics::{Gauge, Histogram};
use crate::trace::{self, Obs, SpanKind};

/// Counters exposed for tests and the perf harness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub executed: usize,
    pub steals: usize,
}

/// Lock-cheap per-tenant scheduling counters, attached to every batch a
/// governed tenant opens ([`WorkerPool::batch_with`]). Workers bump these
/// with relaxed atomics while holding the pool mutex anyway, so the cost
/// over an ungoverned batch is a handful of uncontended increments; the
/// scoreboard ([`crate::govern`]) snapshots them mid-flight without
/// stopping the pool.
#[derive(Debug, Default)]
pub struct QosCounters {
    /// Tasks submitted under this tenant's batches.
    pub submitted: AtomicU64,
    /// Tasks finished (including panicked ones — they consumed a worker).
    pub executed: AtomicU64,
    /// Tasks taken from a sibling worker's deque.
    pub steals: AtomicU64,
    /// Times a worker skipped one of this tenant's submissions that had
    /// queued work because its round-robin credit was exhausted — the
    /// preemption-by-not-picking observable: higher-quota tenants were
    /// served first.
    pub preempted: AtomicU64,
}

/// A batch-mode work-stealing pool.
#[derive(Debug)]
pub struct TaskPool {
    threads: usize,
}

impl TaskPool {
    /// A pool with `threads` workers (≥ 1).
    pub fn new(threads: usize) -> Self {
        TaskPool {
            threads: threads.max(1),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion; returns scheduling stats.
    ///
    /// Tasks are `FnOnce` closures that may borrow non-`'static` state
    /// (scoped threads). Panics in tasks propagate after all workers join.
    pub fn run<'scope, F>(&self, tasks: Vec<F>) -> PoolStats
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        if tasks.is_empty() {
            return PoolStats::default();
        }
        let n_workers = self.threads.min(tasks.len()).max(1);
        // Seed the deques round-robin.
        let queues: Vec<Mutex<VecDeque<F>>> = (0..n_workers)
            .map(|_| Mutex::new(VecDeque::new()))
            .collect();
        for (i, t) in tasks.into_iter().enumerate() {
            queues[i % n_workers].lock().unwrap().push_back(t);
        }
        let executed = AtomicUsize::new(0);
        let steals = AtomicUsize::new(0);

        std::thread::scope(|s| {
            for wid in 0..n_workers {
                let queues = &queues;
                let executed = &executed;
                let steals = &steals;
                s.spawn(move || {
                    loop {
                        // Own queue first: LIFO end (most recently pushed →
                        // warm caches for recursive spawn patterns).
                        let task = queues[wid].lock().unwrap().pop_back();
                        if let Some(t) = task {
                            t(wid);
                            executed.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        // Steal: scan victims from wid+1, take the FIFO end.
                        let mut stolen = None;
                        for off in 1..n_workers {
                            let victim = (wid + off) % n_workers;
                            if let Some(t) = queues[victim].lock().unwrap().pop_front() {
                                stolen = Some(t);
                                break;
                            }
                        }
                        match stolen {
                            Some(t) => {
                                steals.fetch_add(1, Ordering::Relaxed);
                                t(wid);
                                executed.fetch_add(1, Ordering::Relaxed);
                            }
                            // All queues empty: batch mode → done.
                            None => break,
                        }
                    }
                });
            }
        });

        PoolStats {
            executed: executed.load(Ordering::Relaxed),
            steals: steals.load(Ordering::Relaxed),
        }
    }

    /// Convenience: run the same closure over every index in `0..n` with
    /// automatic chunking — the map-phase shape.
    pub fn run_indexed<'scope, F>(&self, n: usize, f: F) -> PoolStats
    where
        F: Fn(usize, usize) + Send + Sync + 'scope,
    {
        // One task per chunk; ~4 chunks per worker balances stealing
        // opportunity against queue traffic (Phoenix uses a similar
        // heuristic for its task granularity).
        let chunks = super::splitter::split_indices(n, self.threads * 4);
        let f = &f;
        self.run(
            chunks
                .into_iter()
                .map(|range| {
                    move |wid: usize| {
                        for i in range {
                            f(wid, i);
                        }
                    }
                })
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------
// Persistent worker pool — the multi-tenant session scheduler
// ---------------------------------------------------------------------

/// A task queued on a persistent worker (lifetime-erased; see the safety
/// argument on [`WorkerPool::run`]).
type Job = Box<dyn FnOnce(usize) + Send + 'static>;

/// Identifies one tenant batch on a [`WorkerPool`]. Every submission made
/// through one [`Batch`] handle (a job's map phase, then its
/// reduce/finalize phase) carries the same id, so a tenant's scheduling
/// activity is observable end to end ([`WorkerPool::snapshot`],
/// [`crate::coordinator::pipeline::FlowMetrics`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchId(pub u64);

/// One in-flight submission: a flat set of tasks with its own deques and
/// its own pending/executed/steals/panicked counters plus completion
/// condvar. The whole-pool mutex guards the bookkeeping, but nothing
/// serializes *across* submissions — concurrent tenants share the workers
/// at task granularity.
struct Submission {
    /// Unique per submission (monotonic), the wait key.
    sub: u64,
    /// Tenant tag: shared by all submissions of one [`Batch`] handle.
    id: BatchId,
    /// One deque per eligible worker (`wid < workers`), seeded round-robin.
    queues: Vec<VecDeque<Job>>,
    /// Worker-concurrency cap for this submission (a session pool sized
    /// for the machine can still run a 1-thread ablation job).
    workers: usize,
    /// Weighted-round-robin share: how many tasks this submission may be
    /// served per credit round (≥ 1; ungoverned batches get 1, governed
    /// ones `priority multiplier × tenant weight` — see [`crate::govern`]).
    quota: u32,
    /// Remaining credit in the current round. Decremented per pick; when
    /// every runnable submission is out of credit, all credits refresh to
    /// their quotas (deficit round-robin), so no submission ever starves.
    credit: u32,
    /// Per-tenant scheduling counters, when the batch is governed.
    counters: Option<Arc<QosCounters>>,
    /// Queued-or-running tasks not yet finished.
    pending: usize,
    executed: usize,
    steals: usize,
    panicked: usize,
    /// The submitting thread sleeps here until `pending == 0`.
    done_cv: Arc<Condvar>,
}

struct PoolState {
    /// Every in-flight submission, oldest first.
    subs: Vec<Submission>,
    /// Fairness cursor: the submission index an idle worker scans first,
    /// advanced past each served submission so active batches take turns
    /// at task granularity (no batch starves while another has queued
    /// tasks).
    rr: usize,
    /// Pool-lifetime totals — per-batch stats sum to these (asserted by
    /// the testkit fairness property).
    total_executed: usize,
    total_steals: usize,
    shutdown: bool,
}

impl PoolState {
    /// Pop a task for `wid` from one submission: own deque first (LIFO
    /// end, cache-warm), then steal from victims (FIFO end). Returns the
    /// task and whether it was stolen.
    fn take(s: &mut Submission, wid: usize) -> Option<(Job, bool)> {
        if let Some(t) = s.queues[wid].pop_back() {
            return Some((t, false));
        }
        for soff in 1..s.workers {
            let victim = (wid + soff) % s.workers;
            if let Some(t) = s.queues[victim].pop_front() {
                return Some((t, true));
            }
        }
        None
    }

    /// The fair pick — **weighted** round-robin with credits (deficit
    /// round-robin): scan submissions ring-order from the cursor, serving
    /// only those with remaining `credit`; a zero-credit submission that
    /// still has queued work is skipped (preemption-by-not-picking,
    /// counted on its [`QosCounters`]). When every submission runnable by
    /// this worker is out of credit, all credits refresh to their quotas
    /// and the scan repeats — so a pick is guaranteed whenever any
    /// submission has work for this worker, and with uniform quotas the
    /// order degenerates to the classic unweighted round-robin. Returns
    /// the submission index, the task, and whether it was stolen.
    fn pick(&mut self, wid: usize) -> Option<(usize, Job, bool)> {
        let n = self.subs.len();
        if n == 0 {
            return None;
        }
        let start = self.rr % n;
        for off in 0..n {
            let si = (start + off) % n;
            let s = &mut self.subs[si];
            if wid >= s.workers {
                continue;
            }
            if s.credit == 0 {
                if s.queues.iter().any(|q| !q.is_empty()) {
                    if let Some(c) = &s.counters {
                        c.preempted.fetch_add(1, Ordering::Relaxed);
                    }
                }
                continue;
            }
            if let Some((t, stolen)) = Self::take(s, wid) {
                s.credit -= 1;
                return Some((si, t, stolen));
            }
        }
        // Every submission with credit left had no queued work for this
        // worker. If none is runnable even ignoring credit, the worker
        // sleeps; otherwise start a fresh credit round and rescan — the
        // rescan always finds the work the credit check skipped.
        let runnable = self
            .subs
            .iter()
            .any(|s| wid < s.workers && s.queues.iter().any(|q| !q.is_empty()));
        if !runnable {
            return None;
        }
        for s in &mut self.subs {
            s.credit = s.quota;
        }
        for off in 0..n {
            let si = (start + off) % n;
            let s = &mut self.subs[si];
            if wid >= s.workers {
                continue;
            }
            if let Some((t, stolen)) = Self::take(s, wid) {
                s.credit -= 1;
                return Some((si, t, stolen));
            }
        }
        None
    }
}

/// Observability handles the pool publishes into once attached
/// ([`WorkerPool::attach_obs`]): the session tracer plus pre-resolved
/// instrument `Arc`s, so the per-task hot path never touches the
/// registry map.
struct PoolObs {
    obs: Obs,
    /// `pool.task_us` — per-task wall time histogram.
    task_us: Arc<Histogram>,
    /// `pool.queue_depth` — tasks sitting in submission deques right now.
    queue_depth: Arc<Gauge>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers sleep here when no submission has a task for them.
    work_cv: Condvar,
    /// Late-bound observability (unattached pools — tests, baselines —
    /// pay one `OnceLock` load per task and nothing else).
    obs: OnceLock<PoolObs>,
}

impl PoolShared {
    /// Lock the state, shrugging off poisoning: a panicking task is caught
    /// before it can poison anything, and batch completion must survive
    /// sibling panics so the borrow-based safety argument holds.
    fn lock(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// An observable view of one in-flight batch ([`WorkerPool::snapshot`]):
/// the overlap evidence the concurrency tests assert (two tenants both
/// report executed tasks while a long batch is still pending).
#[derive(Clone, Copy, Debug)]
pub struct BatchSnapshot {
    pub id: BatchId,
    pub pending: usize,
    pub executed: usize,
    pub steals: usize,
    pub panicked: usize,
    /// Tasks still sitting in the submission's deques right now — the
    /// genuinely *queued* share of `pending` (the remainder is currently
    /// running on workers). This is the live depth the governance
    /// scoreboard reports per tenant.
    pub queue_depth: usize,
}

/// A **persistent, multi-tenant** work-stealing pool: worker OS threads
/// are spawned once per session and shared by every concurrently running
/// job, unlike [`TaskPool`] which scopes a fresh set of threads to each
/// `run` call.
///
/// This is the pool a [`crate::api::Runtime`] owns. A k-means pipeline
/// running 5 Lloyd iterations pays thread-spawn cost once, not 10× (map +
/// reduce per iteration); [`WorkerPool::spawned_threads`] makes the reuse
/// observable to tests.
///
/// Concurrency model: each `run`/[`Batch::run`] call submits a tagged
/// batch of tasks and blocks until *that batch* drains. Submissions from
/// different threads proceed in parallel — workers pull round-robin
/// across the active batches (fairness) and steal within a batch
/// (balance). A task panic is caught on the worker, counted against its
/// own batch, and re-raised only on that batch's submitting thread after
/// the batch drains; other tenants are unaffected.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    spawned: AtomicUsize,
    next_batch: AtomicU64,
    next_sub: AtomicU64,
}

impl WorkerPool {
    /// A session pool with `threads` workers spawned up front (≥ 1). The
    /// pool grows on demand if a later job asks for more workers.
    pub fn new(threads: usize) -> Self {
        let pool = WorkerPool {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    subs: Vec::new(),
                    rr: 0,
                    total_executed: 0,
                    total_steals: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                obs: OnceLock::new(),
            }),
            handles: Mutex::new(Vec::new()),
            spawned: AtomicUsize::new(0),
            next_batch: AtomicU64::new(0),
            next_sub: AtomicU64::new(0),
        };
        pool.ensure_workers(threads.max(1));
        pool
    }

    /// Total worker threads ever spawned by this pool — the session-reuse
    /// observable: two jobs on one pool leave this unchanged.
    pub fn spawned_threads(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Attach the session observability handle (idempotent; first caller
    /// wins). Workers then record a [`SpanKind::Task`] span per executed
    /// task, submits record [`SpanKind::Batch`] spans, and the pool
    /// publishes `pool.task_us` / `pool.queue_depth` metrics.
    pub fn attach_obs(&self, obs: Obs) {
        let task_us = obs.metrics.histogram("pool.task_us");
        let queue_depth = obs.metrics.gauge("pool.queue_depth");
        let _ = self.shared.obs.set(PoolObs {
            obs,
            task_us,
            queue_depth,
        });
    }

    /// The attached observability handle, if any.
    pub fn obs(&self) -> Option<&Obs> {
        self.shared.obs.get().map(|o| &o.obs)
    }

    /// Spawn workers until at least `n` exist.
    fn ensure_workers(&self, n: usize) {
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        let current = self.spawned.load(Ordering::SeqCst);
        if current >= n {
            return;
        }
        for wid in current..n {
            let shared = Arc::clone(&self.shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mr4r-worker-{wid}"))
                    .spawn(move || worker_loop(&shared, wid))
                    .expect("spawn pool worker"),
            );
        }
        self.spawned.store(n, Ordering::SeqCst);
    }

    /// Open a tagged batch handle: all submissions made through it share
    /// one [`BatchId`] and accumulate into one [`Batch::stats`]. One
    /// handle per job (or per plan stage) is the pipeline convention.
    /// Ungoverned: round-robin quota 1, no tenant counters.
    pub fn batch(&self) -> Batch<'_> {
        self.batch_with(1, None)
    }

    /// [`WorkerPool::batch`] with an explicit weighted-round-robin `quota`
    /// (clamped ≥ 1) and optional per-tenant [`QosCounters`] — the
    /// governed entry point: the pipeline opens every job of a registered
    /// tenant through this, so the tenant's priority class and weight
    /// shape how often workers serve its submissions (see
    /// [`crate::govern`]).
    pub fn batch_with(&self, quota: u32, counters: Option<Arc<QosCounters>>) -> Batch<'_> {
        Batch {
            pool: self,
            id: BatchId(self.next_batch.fetch_add(1, Ordering::Relaxed)),
            executed: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            quota: quota.max(1),
            counters,
        }
    }

    /// Run every task to completion on at most `workers` of the pool's
    /// threads; returns this batch's scheduling stats. Panics (after the
    /// whole batch has drained) if any task panicked — only on *this*
    /// caller; concurrent batches are unaffected.
    ///
    /// Tasks may borrow non-`'static` state from the caller's stack, like
    /// [`TaskPool::run`]. Safety: each task is lifetime-erased to be
    /// queued on persistent threads, and this function does not return
    /// until every queued task has finished executing (the batch's
    /// `pending` count reaches zero under the pool mutex), so no borrow
    /// outlives the frame that owns it.
    ///
    /// Concurrent `run` calls from different threads interleave fairly;
    /// submitting from *inside* a pool task is still unsupported (with
    /// every worker blocked in a nested submit the pool has no thread
    /// left to drain it) — chain jobs from driver threads instead.
    pub fn run<'scope, F>(&self, workers: usize, tasks: Vec<F>) -> PoolStats
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        self.batch().run(workers, tasks)
    }

    /// The in-flight batches, for observability (tests assert overlap:
    /// a short tenant's finished batch reported executed tasks while a
    /// long tenant's batch still shows `pending > 0`).
    pub fn snapshot(&self) -> Vec<BatchSnapshot> {
        let state = self.shared.lock();
        let mut out = Vec::with_capacity(state.subs.len());
        for s in &state.subs {
            out.push(BatchSnapshot {
                id: s.id,
                pending: s.pending,
                executed: s.executed,
                steals: s.steals,
                panicked: s.panicked,
                queue_depth: s.queues.iter().map(VecDeque::len).sum(),
            });
        }
        out
    }

    /// Number of in-flight batches right now.
    pub fn active_batches(&self) -> usize {
        self.shared.lock().subs.len()
    }

    /// Pool-lifetime totals across every batch ever run — governed
    /// ([`WorkerPool::batch_with`]) and ungoverned batches alike count
    /// here. Per-batch [`PoolStats`] returned by `run` sum exactly to the
    /// delta of this between any two quiescent points.
    pub fn totals(&self) -> PoolStats {
        let state = self.shared.lock();
        PoolStats {
            executed: state.total_executed,
            steals: state.total_steals,
        }
    }

    /// Submit one tagged task set and block until it drains. Returns the
    /// submission's stats and panicked count (the caller decides how to
    /// surface panics).
    fn submit<'scope, F>(
        &self,
        id: BatchId,
        workers: usize,
        quota: u32,
        counters: Option<Arc<QosCounters>>,
        tasks: Vec<F>,
    ) -> (PoolStats, usize)
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        if tasks.is_empty() {
            return (PoolStats::default(), 0);
        }
        // One `Batch` span per submission, submit → drain; args learn the
        // executed-task count at drain (a = batch id, b = executed).
        let mut batch_span = self
            .shared
            .obs
            .get()
            .map(|o| o.obs.tracer.span(SpanKind::Batch, id.0, 0));
        let workers = workers.max(1).min(tasks.len());
        self.ensure_workers(workers);
        let sub = self.next_sub.fetch_add(1, Ordering::Relaxed);
        let done_cv = Arc::new(Condvar::new());
        let n_tasks = tasks.len();
        // Box and seed the deques *before* taking the pool mutex: the
        // enqueue work depends on nothing behind the lock, and stalling
        // every worker while a large batch boxes its tasks would
        // reintroduce cross-tenant head-of-line blocking.
        let mut queues: Vec<VecDeque<Job>> = (0..workers).map(|_| VecDeque::new()).collect();
        for (i, t) in tasks.into_iter().enumerate() {
            let job: Box<dyn FnOnce(usize) + Send + 'scope> = Box::new(t);
            // SAFETY: see above — the wait loop below keeps every
            // borrow in `job` alive until the job has run.
            let job: Job = unsafe { std::mem::transmute(job) };
            queues[i % workers].push_back(job);
        }
        if let Some(c) = &counters {
            c.submitted.fetch_add(n_tasks as u64, Ordering::Relaxed);
        }
        {
            let mut state = self.shared.lock();
            state.subs.push(Submission {
                sub,
                id,
                queues,
                workers,
                quota,
                credit: quota,
                counters,
                pending: n_tasks,
                executed: 0,
                steals: 0,
                panicked: 0,
                done_cv: Arc::clone(&done_cv),
            });
            if let Some(o) = self.shared.obs.get() {
                let depth: usize = state
                    .subs
                    .iter()
                    .flat_map(|s| s.queues.iter())
                    .map(VecDeque::len)
                    .sum();
                o.queue_depth.set(depth as u64);
            }
        }
        self.shared.work_cv.notify_all();

        let mut state = self.shared.lock();
        loop {
            let idx = state
                .subs
                .iter()
                .position(|s| s.sub == sub)
                .expect("in-flight submission stays listed until removed here");
            if state.subs[idx].pending == 0 {
                let done = state.subs.remove(idx);
                if !state.subs.is_empty() {
                    state.rr %= state.subs.len();
                }
                drop(state);
                if let Some(span) = batch_span.as_mut() {
                    span.set_args(id.0, done.executed as u64);
                }
                let stats = PoolStats {
                    executed: done.executed,
                    steals: done.steals,
                };
                return (stats, done.panicked);
            }
            state = done_cv.wait(state).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.lock().shutdown = true;
        self.shared.work_cv.notify_all();
        let handles = std::mem::take(
            &mut *self.handles.lock().unwrap_or_else(|e| e.into_inner()),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

/// A tagged batch handle on a [`WorkerPool`]: the per-tenant scheduling
/// surface the pipeline threads through a job's phases. Each [`Batch::run`]
/// is one submission under this handle's [`BatchId`]; [`Batch::stats`]
/// accumulates across them (map + reduce/finalize), giving the per-batch
/// `PoolStats` that the concurrency acceptance criteria observe.
pub struct Batch<'p> {
    pool: &'p WorkerPool,
    id: BatchId,
    executed: AtomicUsize,
    steals: AtomicUsize,
    /// Weighted-round-robin share each submission of this handle gets.
    quota: u32,
    /// Tenant counters threaded into each submission (governed batches).
    counters: Option<Arc<QosCounters>>,
}

impl<'p> Batch<'p> {
    pub fn id(&self) -> BatchId {
        self.id
    }

    pub fn pool(&self) -> &'p WorkerPool {
        self.pool
    }

    /// Submit tasks under this batch's id and block until they drain; see
    /// [`WorkerPool::run`] for the execution and panic contract.
    pub fn run<'scope, F>(&self, workers: usize, tasks: Vec<F>) -> PoolStats
    where
        F: FnOnce(usize) + Send + 'scope,
    {
        let (stats, panicked) =
            self.pool
                .submit(self.id, workers, self.quota, self.counters.clone(), tasks);
        self.executed.fetch_add(stats.executed, Ordering::Relaxed);
        self.steals.fetch_add(stats.steals, Ordering::Relaxed);
        if panicked > 0 {
            panic!("{panicked} worker-pool task(s) panicked in batch {:?}", self.id);
        }
        stats
    }

    /// Cumulative stats across every submission made through this handle.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            executed: self.executed.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
        }
    }
}

fn worker_loop(shared: &PoolShared, wid: usize) {
    // Chrome-trace rows key on tid: pin this thread's tid to the worker
    // index before anything records.
    trace::set_thread_tid(wid as u64);
    let mut state = shared.lock();
    loop {
        if state.shutdown {
            return;
        }
        match state.pick(wid) {
            Some((si, task, stolen)) => {
                // Advance the fairness cursor past the served batch so the
                // next seeker starts at the following one.
                state.rr = (si + 1) % state.subs.len();
                if stolen {
                    state.total_steals += 1;
                }
                let s = &mut state.subs[si];
                if stolen {
                    s.steals += 1;
                    if let Some(c) = &s.counters {
                        c.steals.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let sub = s.sub;
                let bid = s.id;
                drop(state);
                let obs = shared.obs.get();
                let start_us = obs.map(|o| o.obs.tracer.now_us());
                // Panic isolation: catch here so one tenant's panicking
                // mapper cannot take down the worker (or any other
                // tenant); the count is re-raised on the owning batch's
                // submitting thread after its drain.
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(wid)))
                    .is_ok();
                if let (Some(o), Some(start)) = (obs, start_us) {
                    // Exactly one `Task` span per executed task — the
                    // reconciliation invariant the trace tests assert
                    // against the scheduler's `executed` totals.
                    o.task_us
                        .record(o.obs.tracer.now_us().saturating_sub(start));
                    o.obs
                        .tracer
                        .record_since(SpanKind::Task, start, bid.0, u64::from(!ok));
                }
                state = shared.lock();
                state.total_executed += 1;
                if let Some(s) = state.subs.iter_mut().find(|s| s.sub == sub) {
                    s.executed += 1;
                    if let Some(c) = &s.counters {
                        c.executed.fetch_add(1, Ordering::Relaxed);
                    }
                    if !ok {
                        s.panicked += 1;
                    }
                    s.pending -= 1;
                    if s.pending == 0 {
                        let cv = Arc::clone(&s.done_cv);
                        cv.notify_all();
                    }
                }
            }
            None => {
                state = shared
                    .work_cv
                    .wait(state)
                    .unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

/// Drain a synthetic set of batches through the pool's **real** pick
/// policy, single-threaded and without any timing: batch `b` contributes
/// `batch_sizes[b]` no-op tasks (each seeded round-robin over `workers`
/// deques), one simulated worker executes tasks one at a time with its
/// `wid` cycling through `0..workers`, and the return value records, per
/// executed task, the ordinal of the batch it came from.
///
/// This is the deterministic substrate for the testkit fairness property:
/// round-robin progress invariants can be asserted exactly, with no
/// dependence on OS thread interleaving.
#[doc(hidden)]
pub fn simulate_pick_order(batch_sizes: &[usize], workers: usize) -> Vec<usize> {
    let weighted: Vec<(usize, u32)> = batch_sizes.iter().map(|&n| (n, 1)).collect();
    simulate_pick_order_weighted(&weighted, workers)
}

/// [`simulate_pick_order`] with a per-batch weighted-round-robin quota:
/// batch `b` contributes `batches[b].0` tasks and is served up to
/// `batches[b].1` picks per credit round. With uniform quotas this is
/// exactly the unweighted simulation; with mixed quotas it is the
/// deterministic substrate for the QoS share property tests.
#[doc(hidden)]
pub fn simulate_pick_order_weighted(batches: &[(usize, u32)], workers: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let mut state = PoolState {
        subs: Vec::new(),
        rr: 0,
        total_executed: 0,
        total_steals: 0,
        shutdown: false,
    };
    for (ord, &(n, quota)) in batches.iter().enumerate() {
        if n == 0 {
            continue;
        }
        let quota = quota.max(1);
        let mut queues: Vec<VecDeque<Job>> = (0..workers).map(|_| VecDeque::new()).collect();
        for i in 0..n {
            let job: Job = Box::new(|_wid| {});
            queues[i % workers].push_back(job);
        }
        state.subs.push(Submission {
            sub: ord as u64,
            id: BatchId(ord as u64),
            queues,
            workers,
            quota,
            credit: quota,
            counters: None,
            pending: n,
            executed: 0,
            steals: 0,
            panicked: 0,
            done_cv: Arc::new(Condvar::new()),
        });
    }
    let mut order = Vec::new();
    let mut wid = 0usize;
    loop {
        match state.pick(wid) {
            Some((si, task, stolen)) => {
                // Mirror `worker_loop`: cursor past the served batch, then
                // bookkeeping, then execution, then drain handling.
                state.rr = (si + 1) % state.subs.len();
                let s = &mut state.subs[si];
                if stolen {
                    s.steals += 1;
                }
                s.executed += 1;
                s.pending -= 1;
                order.push(s.id.0 as usize);
                let drained = s.pending == 0;
                task(wid);
                if drained {
                    state.subs.remove(si);
                    if !state.subs.is_empty() {
                        state.rr %= state.subs.len();
                    } else {
                        state.rr = 0;
                    }
                }
            }
            None => break,
        }
        wid = (wid + 1) % workers;
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn executes_every_task_exactly_once() {
        let pool = TaskPool::new(4);
        let n = 1000;
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..n)
            .map(|_| {
                let c = &counter;
                move |_wid: usize| {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        let stats = pool.run(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), n);
        assert_eq!(stats.executed, n);
    }

    #[test]
    fn empty_batch_is_fine() {
        let pool = TaskPool::new(4);
        let stats = pool.run(Vec::<fn(usize)>::new());
        assert_eq!(stats.executed, 0);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = TaskPool::new(1);
        let acc = AtomicU64::new(0);
        let tasks: Vec<_> = (0..64u64)
            .map(|i| {
                let acc = &acc;
                move |_w: usize| {
                    acc.fetch_add(i, Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(tasks);
        assert_eq!(acc.load(Ordering::Relaxed), 63 * 64 / 2);
    }

    #[test]
    fn imbalanced_tasks_get_stolen() {
        // A long task placed at the LIFO end of worker 0's queue: worker 0
        // pops it first and blocks; its remaining short tasks can only be
        // finished by worker 1 stealing them.
        let pool = TaskPool::new(2);
        let done = AtomicUsize::new(0);
        let done_ref = &done;
        let n_short = 400;
        let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
        for _ in 0..n_short {
            tasks.push(Box::new(move |_w| {
                std::thread::sleep(std::time::Duration::from_micros(50));
                done_ref.fetch_add(1, Ordering::Relaxed);
            }));
        }
        // Index 400 % 2 == 0 → back of worker 0's deque → popped first.
        tasks.push(Box::new(move |_w| {
            std::thread::sleep(std::time::Duration::from_millis(60));
            done_ref.fetch_add(1, Ordering::Relaxed);
        }));
        let stats = pool.run(tasks);
        assert_eq!(done.load(Ordering::Relaxed), n_short + 1);
        assert!(stats.steals > 0, "expected steals on imbalanced load");
    }

    #[test]
    fn run_indexed_covers_range() {
        let pool = TaskPool::new(3);
        let n = 997; // prime → uneven chunks
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run_indexed(n, |_wid, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn tasks_can_borrow_stack_state() {
        let pool = TaskPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        pool.run_indexed(data.len(), |_w, i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn worker_ids_are_in_range() {
        let pool = TaskPool::new(4);
        let bad = AtomicUsize::new(0);
        pool.run_indexed(200, |wid, _i| {
            if wid >= 4 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
    }

    // ---- WorkerPool (persistent session pool) ----

    fn counting_tasks(n: usize, counter: &AtomicUsize) -> Vec<impl FnOnce(usize) + Send + '_> {
        (0..n)
            .map(|_| {
                move |_wid: usize| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect()
    }

    #[test]
    fn worker_pool_executes_every_task() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        let stats = pool.run(4, counting_tasks(1000, &counter));
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(stats.executed, 1000);
    }

    #[test]
    fn worker_pool_reuses_threads_across_batches() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.spawned_threads(), 3);
        let counter = AtomicUsize::new(0);
        for _ in 0..5 {
            pool.run(3, counting_tasks(50, &counter));
        }
        assert_eq!(counter.load(Ordering::Relaxed), 250);
        assert_eq!(pool.spawned_threads(), 3, "no respawn across batches");
    }

    #[test]
    fn worker_pool_grows_on_demand() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.spawned_threads(), 1);
        let counter = AtomicUsize::new(0);
        pool.run(4, counting_tasks(100, &counter));
        assert_eq!(pool.spawned_threads(), 4);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn worker_pool_respects_batch_worker_limit() {
        let pool = WorkerPool::new(4);
        let seen = Mutex::new(std::collections::HashSet::new());
        let tasks: Vec<_> = (0..200)
            .map(|_| {
                let seen = &seen;
                move |wid: usize| {
                    seen.lock().unwrap().insert(wid);
                }
            })
            .collect();
        pool.run(2, tasks);
        assert!(seen.lock().unwrap().iter().all(|&w| w < 2));
    }

    #[test]
    fn worker_pool_tasks_borrow_stack_state() {
        let pool = WorkerPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        let tasks: Vec<_> = (0..data.len())
            .map(|i| {
                let data = &data;
                let sum = &sum;
                move |_wid: usize| {
                    sum.fetch_add(data[i], Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(2, tasks);
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn worker_pool_steals_imbalanced_load() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let done_ref = &done;
        let n_short = 400;
        let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
        for _ in 0..n_short {
            tasks.push(Box::new(move |_w| {
                std::thread::sleep(std::time::Duration::from_micros(50));
                done_ref.fetch_add(1, Ordering::Relaxed);
            }));
        }
        // Index 400 % 2 == 0 → back of worker 0's deque → popped first.
        tasks.push(Box::new(move |_w| {
            std::thread::sleep(std::time::Duration::from_millis(60));
            done_ref.fetch_add(1, Ordering::Relaxed);
        }));
        let stats = pool.run(2, tasks);
        assert_eq!(done.load(Ordering::Relaxed), n_short + 1);
        assert!(stats.steals > 0, "expected steals on imbalanced load");
    }

    #[test]
    fn worker_pool_empty_batch_is_fine() {
        let pool = WorkerPool::new(2);
        let stats = pool.run(2, Vec::<fn(usize)>::new());
        assert_eq!(stats.executed, 0);
    }

    #[test]
    fn worker_pool_propagates_task_panics_after_drain() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
            tasks.push(Box::new(|_w| panic!("boom")));
            for _ in 0..50 {
                tasks.push(Box::new(|_w| {
                    done.fetch_add(1, Ordering::Relaxed);
                }));
            }
            pool.run(2, tasks);
        }));
        assert!(result.is_err(), "task panic must propagate");
        assert_eq!(done.load(Ordering::Relaxed), 50, "siblings still run");
        // The pool survives for the next batch.
        let counter = AtomicUsize::new(0);
        pool.run(2, counting_tasks(10, &counter));
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    // ---- Multi-tenant behaviour ----

    #[test]
    fn concurrent_batches_from_two_threads_both_complete() {
        let pool = WorkerPool::new(4);
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let pool = &pool;
            let a = &a;
            let b = &b;
            s.spawn(move || {
                pool.run(4, counting_tasks(500, a));
            });
            s.spawn(move || {
                pool.run(4, counting_tasks(500, b));
            });
        });
        assert_eq!(a.load(Ordering::Relaxed), 500);
        assert_eq!(b.load(Ordering::Relaxed), 500);
        assert_eq!(pool.active_batches(), 0, "all batches drained");
    }

    #[test]
    fn batch_handle_accumulates_phase_stats() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        let batch = pool.batch();
        batch.run(2, counting_tasks(30, &counter));
        batch.run(2, counting_tasks(20, &counter));
        assert_eq!(batch.stats().executed, 50);
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn panic_in_one_batch_leaves_concurrent_batch_intact() {
        let pool = WorkerPool::new(2);
        let good = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let pool = &pool;
            let good = &good;
            let bad = s.spawn(move || {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut tasks: Vec<Box<dyn FnOnce(usize) + Send>> = Vec::new();
                    for i in 0..40 {
                        tasks.push(Box::new(move |_w| {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                            if i == 7 {
                                panic!("tenant A boom");
                            }
                        }));
                    }
                    pool.run(2, tasks);
                }))
            });
            let tasks: Vec<_> = (0..200)
                .map(|_| {
                    move |_w: usize| {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                        good.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect();
            let stats = pool.run(2, tasks);
            assert_eq!(stats.executed, 200);
            assert!(bad.join().unwrap().is_err(), "panic surfaces only at A's submit");
        });
        assert_eq!(good.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn totals_accumulate_across_batches() {
        let pool = WorkerPool::new(2);
        let before = pool.totals();
        let counter = AtomicUsize::new(0);
        let s1 = pool.run(2, counting_tasks(40, &counter));
        let s2 = pool.run(2, counting_tasks(60, &counter));
        let after = pool.totals();
        assert_eq!(after.executed - before.executed, s1.executed + s2.executed);
        assert_eq!(after.steals - before.steals, s1.steals + s2.steals);
    }

    #[test]
    fn simulate_pick_order_is_round_robin() {
        // Three batches of 4 tasks on one simulated worker: strict
        // alternation until batches drain.
        let order = simulate_pick_order(&[4, 4, 4], 1);
        assert_eq!(order.len(), 12);
        assert_eq!(&order[..6], &[0, 1, 2, 0, 1, 2]);
        // Unequal batches: the longer one finishes last but is never
        // served twice while another batch still has tasks queued.
        let order = simulate_pick_order(&[8, 2], 1);
        assert_eq!(order.len(), 10);
        assert_eq!(&order[..4], &[0, 1, 0, 1]);
        assert!(order[4..].iter().all(|&b| b == 0));
    }

    #[test]
    fn weighted_quota_biases_pick_order() {
        // Quota 2 vs 1: in each credit round batch 0 is served twice for
        // every one serve of batch 1 (deficit round-robin), and batch 1
        // still progresses every round — weighted share without
        // starvation.
        let order = simulate_pick_order_weighted(&[(6, 2), (3, 1)], 1);
        assert_eq!(order, vec![0, 1, 0, 1, 0, 0, 1, 0, 0]);
        // Uniform quotas degenerate to the classic round-robin.
        assert_eq!(
            simulate_pick_order_weighted(&[(4, 1), (4, 1), (4, 1)], 1),
            simulate_pick_order(&[4, 4, 4], 1),
        );
    }

    #[test]
    fn zero_credit_submissions_count_preemptions() {
        // Direct PoolState surgery: a zero-credit submission with queued
        // work must be skipped (and its preemption counted) in favour of a
        // submission that still has credit.
        let c0 = Arc::new(QosCounters::default());
        let mk = |sub: u64, credit: u32, counters: Option<Arc<QosCounters>>| {
            let mut queues: Vec<VecDeque<Job>> = vec![VecDeque::new()];
            queues[0].push_back(Box::new(|_wid| {}) as Job);
            Submission {
                sub,
                id: BatchId(sub),
                queues,
                workers: 1,
                quota: 1,
                credit,
                counters,
                pending: 1,
                executed: 0,
                steals: 0,
                panicked: 0,
                done_cv: Arc::new(Condvar::new()),
            }
        };
        let mut state = PoolState {
            subs: vec![mk(0, 0, Some(Arc::clone(&c0))), mk(1, 1, None)],
            rr: 0,
            total_executed: 0,
            total_steals: 0,
            shutdown: false,
        };
        let (si, _task, stolen) = state.pick(0).expect("sub 1 has credit and work");
        assert_eq!(si, 1, "zero-credit sub 0 is passed over");
        assert!(!stolen);
        assert_eq!(c0.preempted.load(Ordering::Relaxed), 1);
    }
}
