//! The MR4R runtime coordinator — scheduling, input splitting, intermediate
//! collection, and the two execution flows.
//!
//! The paper's §2.4 names the two central design elements: "the scheduler
//! and the collector of intermediate (key, value) pairs". Here:
//!
//! * [`scheduler`] — a from-scratch work-stealing task pool (the JDK
//!   ForkJoinPool stand-in; nothing like rayon exists in the offline vendor
//!   set, and the paper's framing makes the scheduler part of the system
//!   anyway), in two flavours: the batch-scoped [`TaskPool`] and the
//!   persistent multi-tenant [`WorkerPool`] that [`crate::api::Runtime`]
//!   sessions reuse across jobs — concurrent jobs submit tagged
//!   [`scheduler::Batch`]es and share the workers round-robin at task
//!   granularity.
//! * [`splitter`] — input chunking: "the input is split and individually
//!   passed as an argument to the map method".
//! * [`collector`] — the thread-safe hash table of intermediate pairs, in
//!   two modes: per-key value **lists** (reduce flow) and per-key
//!   **holders** (combining flow). Sharded by key hash to keep lock
//!   contention off the emit hot path.
//! * [`pipeline`] — drives map → (reduce | finalize) with phase barriers,
//!   memsim accounting, and per-phase metrics.
//! * [`planner`] — lowers a lazy [`crate::api::plan::Dataset`]'s logical
//!   stage list to a physical plan via the optimizer agent's whole-plan
//!   pass (element-wise fusion, shard streaming) and carries per-plan
//!   execution state.

pub mod collector;
pub mod pipeline;
pub mod planner;
pub mod scheduler;
pub mod splitter;

pub use collector::{HolderCollector, ListCollector};
pub use pipeline::{run_job, run_job_on, run_job_sharded, FlowMetrics};
pub use planner::{lower, PhysicalPlan};
pub use scheduler::{Batch, BatchId, BatchSnapshot, PoolStats, TaskPool, WorkerPool};
pub use splitter::split_indices;
