//! A small property-based testing kit (proptest stand-in).
//!
//! Design:
//! * a [`Gen`] is a function from a PRNG + size budget to a value;
//! * [`check`] runs N random cases and, on failure, greedily *shrinks* the
//!   failing case via a user-supplied or combinator-derived shrinker;
//! * the failing seed is printed so a case can be replayed exactly by
//!   re-running the test with `MR4R_PROP_SEED=<seed>` in the environment
//!   (and optionally `MR4R_PROP_CASES` to widen the search) — see the
//!   replay workflow in the [module docs](crate::testkit).
//!
//! The goal is not proptest parity — it is covering the invariants listed in
//! DESIGN.md §8 (routing, batching, state, RIR-slicing equivalence) with
//! reproducible random cases.

use crate::util::prng::Xoshiro256;

/// Number of cases per property (env `MR4R_PROP_CASES` overrides).
pub fn default_cases() -> usize {
    std::env::var("MR4R_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// A generator of random values of type `T`.
pub struct Gen<T> {
    #[allow(clippy::type_complexity)]
    f: Box<dyn Fn(&mut Xoshiro256, usize) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Xoshiro256, usize) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Xoshiro256, size: usize) -> T {
        (self.f)(rng, size)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r, s| g(self.sample(r, s)))
    }
}

/// Uniform usize in `[lo, hi]` (inclusive — convenient for sizes).
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(move |r, _| r.range(lo, hi + 1))
}

/// Uniform i64 in `[lo, hi]`.
pub fn i64_in(lo: i64, hi: i64) -> Gen<i64> {
    Gen::new(move |r, _| lo + r.below((hi - lo + 1) as u64) as i64)
}

/// f64 in `[lo, hi)`.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |r, _| r.f64_in(lo, hi))
}

/// Vec of `inner` with length in `[0, max_len]` scaled by the size budget.
pub fn vec_of<T: 'static>(inner: Gen<T>, max_len: usize) -> Gen<Vec<T>> {
    Gen::new(move |r, s| {
        let cap = max_len.min(s.max(1));
        let len = r.range(0, cap + 1);
        (0..len).map(|_| inner.sample(r, s)).collect()
    })
}

/// Short lowercase ASCII word (for key generation).
pub fn word(max_len: usize) -> Gen<String> {
    Gen::new(move |r, _| {
        let len = r.range(1, max_len.max(2));
        (0..len)
            .map(|_| (b'a' + r.below(26) as u8) as char)
            .collect()
    })
}

/// Pick one of a fixed set of values.
pub fn one_of<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty());
    Gen::new(move |r, _| items[r.below(items.len() as u64) as usize].clone())
}

/// Result of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass,
    Fail {
        seed: u64,
        case: T,
        shrunk: Option<T>,
        message: String,
    },
}

/// Run `prop` over `cases` random inputs drawn from `gen`.
/// On failure, attempts to shrink using `shrink` (returns candidate smaller
/// cases; first still-failing candidate is recursed on, up to 200 steps).
pub fn check_with_shrink<T: Clone + std::fmt::Debug + 'static>(
    gen: &Gen<T>,
    cases: usize,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let base_seed = std::env::var("MR4R_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_1234_u64);
    for case_idx in 0..cases {
        let seed = base_seed.wrapping_add(case_idx as u64);
        let mut rng = Xoshiro256::seeded(seed);
        // Size budget grows with the case index so early cases are tiny.
        let size = 1 + case_idx * 64 / cases.max(1);
        let case = gen.sample(&mut rng, size);
        if let Err(msg) = prop(&case) {
            // Greedy shrink.
            let mut best = case.clone();
            let mut best_msg = msg.clone();
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= 200 {
                        break;
                    }
                }
                break;
            }
            return PropResult::Fail {
                seed,
                case,
                shrunk: Some(best),
                message: best_msg,
            };
        }
    }
    PropResult::Pass
}

/// Run a property without shrinking support.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    gen: &Gen<T>,
    cases: usize,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    check_with_shrink(gen, cases, |_| Vec::new(), prop)
}

/// Assert a property holds; panics with the (shrunk) counterexample if not.
/// This is the entry point tests use.
#[track_caller]
pub fn assert_prop<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match check(gen, default_cases(), prop) {
        PropResult::Pass => {}
        PropResult::Fail {
            seed,
            case,
            shrunk,
            message,
        } => panic!(
            "property `{name}` failed (replay with MR4R_PROP_SEED={seed}):\n  \
             message: {message}\n  case: {case:?}\n  shrunk: {shrunk:?}"
        ),
    }
}

/// Assert with a shrinker.
#[track_caller]
pub fn assert_prop_shrink<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match check_with_shrink(gen, default_cases(), shrink, prop) {
        PropResult::Pass => {}
        PropResult::Fail {
            seed,
            case,
            shrunk,
            message,
        } => panic!(
            "property `{name}` failed (replay with MR4R_PROP_SEED={seed}):\n  \
             message: {message}\n  case: {case:?}\n  shrunk: {shrunk:?}"
        ),
    }
}

/// Standard shrinker for vectors: halves, then single-element removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 16 {
        for i in 0..v.len() {
            let mut smaller = v.to_vec();
            smaller.remove(i);
            out.push(smaller);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let g = usize_in(0, 100);
        assert_prop("le-100", &g, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err(format!("{x} > 100"))
            }
        });
    }

    #[test]
    fn failing_property_detected() {
        let g = usize_in(0, 100);
        match check(&g, 256, |&x| {
            if x < 50 {
                Ok(())
            } else {
                Err("too big".into())
            }
        }) {
            PropResult::Fail { .. } => {}
            PropResult::Pass => panic!("should have found a counterexample"),
        }
    }

    #[test]
    fn shrinking_minimizes_vec() {
        // Property: no vector contains a 7. Shrinker should reduce any
        // failing case to a small vector still containing a 7.
        let g = vec_of(usize_in(0, 10), 30);
        match check_with_shrink(&g, 512, |v| shrink_vec(v), |v| {
            if v.contains(&7) {
                Err("has a 7".into())
            } else {
                Ok(())
            }
        }) {
            PropResult::Fail { shrunk, .. } => {
                let s = shrunk.unwrap();
                assert!(s.contains(&7));
                assert!(s.len() <= 3, "not shrunk enough: {s:?}");
            }
            PropResult::Pass => panic!("7 should appear in some vector"),
        }
    }

    #[test]
    fn word_gen_shape() {
        let mut r = Xoshiro256::seeded(1);
        let g = word(6);
        for _ in 0..100 {
            let w = g.sample(&mut r, 10);
            assert!(!w.is_empty() && w.len() <= 6);
            assert!(w.chars().all(|c| c.is_ascii_lowercase()));
        }
    }
}
