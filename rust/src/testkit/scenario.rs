//! Deterministic concurrency scenarios — the multi-tenant test harness.
//!
//! A [`Scenario`] describes a seeded shape: **N driver threads × M plans
//! each**, drawn from the seven benchmark workloads (paper Table 2), all
//! submitted to **one shared [`Runtime`] session**. The harness runs every
//! plan twice:
//!
//! 1. **Serial baseline** — a fresh session, every plan in a fixed order,
//!    one at a time;
//! 2. **Concurrent phase** — a second fresh session shared by N OS driver
//!    threads, each running its M plans back to back.
//!
//! and then checks **pair-for-pair equivalence**: each `(driver, slot)`
//! plan's canonical result digest under concurrency must equal its serial
//! digest. (Digests are the same order-independent canonical forms the
//! cross-framework equivalence suite uses — exact for the integer
//! workloads, 6-significant-digit canonical for the float ones, so
//! summation-order variation never masks a real divergence.)
//!
//! # Determinism and replay
//!
//! Everything about a scenario derives from its `seed` (via the crate's
//! own [`Xoshiro256`]): which benchmark each slot runs, under which
//! optimizer mode, whether its `Dataset::cache()` cut points are
//! live ([`PlanSpec::cached`] — cached slots on the shared session
//! exercise cross-tenant materialization reuse and must still match the
//! serial baselines), whether the slot runs the **streaming plan**
//! instead ([`PlanSpec::stream`] — a seeded multi-chunk feed through a
//! tumbling windowed count, interleaving standing-query chunks with the
//! batch tenants on the same pool), and whether the slot feeds and
//! consults the session's **adaptive statistics store**
//! ([`PlanSpec::adaptive`] — repeated slots then re-lower under measured
//! statistics, which must never change results; [`run_adaptive_repeat`]
//! drives that loop explicitly). On failure the error message
//! contains the seed;
//! re-running with `MR4R_SCENARIO_SEED=<seed>` (see [`scenario_seed`])
//! replays the exact same plan assignment. Thread *interleaving* is of
//! course up to the OS — the point of the harness is that results must
//! not depend on it.
//!
//! ```ignore
//! let kit = ScenarioKit::prepare(0.0005, 42);
//! let sc = Scenario {
//!     seed: scenario_seed(0xC0FFEE),
//!     drivers: 4,
//!     plans_per_driver: 3,
//!     threads: 4,
//! };
//! assert_scenario(&kit, &sc); // panics with the replay seed on mismatch
//! ```

use std::sync::Arc;
use std::time::Duration;

use crate::api::config::{JobConfig, OptimizeMode};
use crate::api::traits::KeyValue;
use crate::api::Runtime;
use crate::benchmarks::backend::Backend;
use crate::benchmarks::{
    datagen, digest_pairs, histogram, kmeans, linear_regression, matrix_multiply, pca,
    string_match, word_count, BenchId,
};
use crate::govern::{OverloadPolicy, Priority, TenantId, TenantSpec};
use crate::memsim::{HeapParams, SimHeap};
use crate::stream::StreamSource;
use crate::util::prng::Xoshiro256;

/// One plan slot in a scenario: which workload runs, under which
/// optimizer mode, and whether `Dataset::cache()` cut points are live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanSpec {
    pub bench: BenchId,
    pub optimize: OptimizeMode,
    /// Whether the plan's materialization-cache cut points store/read
    /// entries (the K-Means slot runs the cache-aware
    /// `kmeans::run_mr4r_traced` driver; for workloads without a cut
    /// this is a no-op). Cached slots on a shared session exercise
    /// cross-tenant reuse — and must still match their serial baselines
    /// digest for digest.
    pub cached: bool,
    /// Whether this slot runs the **streaming** plan instead of `bench`:
    /// a seeded multi-chunk event feed through a tumbling windowed count
    /// ([`crate::stream`]) on the shared session, digested per
    /// `(window, key)`. Streaming tenants interleave with batch tenants
    /// on one pool and must still match their serial baseline digests.
    pub stream: bool,
    /// Whether the slot's plans feed and consult the session's adaptive
    /// statistics store ([`crate::stats`]). Repeated slots on a shared
    /// session then re-lower under measured statistics — and must still
    /// match their (statically lowered) serial baseline digests.
    pub adaptive: bool,
}

/// Scenario shape: `drivers` OS threads × `plans_per_driver` plans each,
/// on one shared session whose pool has `threads` workers.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    /// Master seed: fully determines the per-slot plan assignment.
    pub seed: u64,
    pub drivers: usize,
    pub plans_per_driver: usize,
    /// Worker threads of the shared session pool (and of every job).
    pub threads: usize,
}

/// The scenario seed: `MR4R_SCENARIO_SEED` from the environment (the
/// replay path printed by failing scenarios), else `default`.
pub fn scenario_seed(default: u64) -> u64 {
    std::env::var("MR4R_SCENARIO_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn kv_tuples<K, V>(kv: Vec<KeyValue<K, V>>) -> Vec<(K, V)> {
    kv.into_iter().map(|p| (p.key, p.value)).collect()
}

/// A uniform plan runner: session + per-plan config in, canonical result
/// digest out.
type PlanFn = Box<dyn Fn(&Runtime, &JobConfig) -> u64 + Send + Sync>;

/// Prepared workload catalog: tiny datasets for all seven benchmarks,
/// wrapped as digest-returning runners. Prepare once, reuse across
/// scenarios (datasets are immutable and shared by reference).
pub struct ScenarioKit {
    plans: Vec<(BenchId, PlanFn)>,
    /// The streaming slot's runner (see [`PlanSpec::stream`]).
    stream_plan: PlanFn,
}

/// Seeded event chunks for the streaming slot: `(key, ts)` pairs with
/// non-decreasing event time, pre-split so replay preserves chunk
/// boundaries (the serial and concurrent runs ingest identical feeds).
fn stream_chunks(scale: f64, seed: u64) -> Vec<Vec<(u64, u64)>> {
    let total = ((scale * 2_000_000.0) as usize).clamp(200, 20_000);
    let chunk_len = (total / 8).max(1);
    let mut rng = Xoshiro256::seeded(seed ^ 0x5745_4E44);
    let mut out = Vec::new();
    let mut chunk = Vec::with_capacity(chunk_len);
    let mut ts = 0u64;
    for _ in 0..total {
        ts += rng.below(3);
        chunk.push((rng.below(17), ts));
        if chunk.len() == chunk_len {
            out.push(std::mem::take(&mut chunk));
        }
    }
    if !chunk.is_empty() {
        out.push(chunk);
    }
    out
}

impl ScenarioKit {
    /// Generate every benchmark's dataset at `scale` (keep it tiny —
    /// 0.0005 runs the whole suite in well under a second per plan) with
    /// the native compute backend.
    pub fn prepare(scale: f64, seed: u64) -> ScenarioKit {
        let backend = Backend::Native;
        let mut plans: Vec<(BenchId, PlanFn)> = Vec::new();

        let lines = Arc::new(datagen::wordcount_text(scale, seed));
        plans.push((
            BenchId::WC,
            Box::new(move |rt, cfg| {
                let (out, _m) = word_count::run_mr4r(&lines, rt, cfg);
                digest_pairs(&kv_tuples(out))
            }),
        ));

        let pixels = Arc::new(datagen::histogram_pixels(scale, seed));
        let b = backend.clone();
        plans.push((
            BenchId::HG,
            Box::new(move |rt, cfg| {
                let (out, _m) = histogram::run_mr4r(&pixels, rt, cfg, &b);
                digest_pairs(&kv_tuples(out))
            }),
        ));

        let km = Arc::new(datagen::kmeans_points(scale, seed));
        let b = backend.clone();
        plans.push((
            BenchId::KM,
            Box::new(move |rt, cfg| {
                // The cache-aware Lloyd driver: with `PlanSpec::cached`
                // the iterations reuse the materialized point blocks
                // (and concurrent KM tenants exercise cross-plan reuse);
                // with it disabled the same two-stage plan recomputes —
                // digests must match the serial baseline either way.
                let (cents, _reports) = kmeans::run_mr4r_traced(&km, rt, cfg, &b);
                kmeans::digest_centroids(&cents)
            }),
        ));

        let pts = Arc::new(datagen::linreg_points(scale, seed));
        let n = pts.len();
        let b = backend.clone();
        plans.push((
            BenchId::LR,
            Box::new(move |rt, cfg| {
                let (out, _m) = linear_regression::run_mr4r(&pts, rt, cfg, &b);
                linear_regression::digest_fit(&kv_tuples(out), n)
            }),
        ));

        let mm = matrix_multiply::prepare(scale, seed);
        let b = backend.clone();
        plans.push((
            BenchId::MM,
            Box::new(move |rt, cfg| {
                let (out, _m) = matrix_multiply::run_mr4r(&mm.a, &mm.b, rt, cfg, &b);
                digest_pairs(&kv_tuples(out))
            }),
        ));

        let pc = pca::prepare(scale, seed);
        let n = pc.matrix.n;
        let b = backend.clone();
        plans.push((
            BenchId::PC,
            Box::new(move |rt, cfg| {
                let (out, _m) = pca::run_mr4r(&pc.matrix, &pc.pairs, rt, cfg, &b);
                pca::digest_cov(&kv_tuples(out), n)
            }),
        ));

        let sm = string_match::prepare(scale, seed);
        plans.push((
            BenchId::SM,
            Box::new(move |rt, cfg| {
                let (out, _m) = string_match::run_mr4r(&sm, rt, cfg);
                digest_pairs(&kv_tuples(out))
            }),
        ));

        // The streaming slot: replay the seeded chunk feed through a
        // tumbling windowed count on the shared session, digesting every
        // fired window's per-key counts. Runs under the slot's optimizer
        // mode, so both the holder-merge path and the buffered fallback
        // are exercised against the same serial baseline.
        let events = Arc::new(stream_chunks(scale, seed));
        let stream_plan: PlanFn = Box::new(move |rt, cfg| {
            let out = rt
                .stream(StreamSource::replay((*events).clone()))
                .with_config(cfg.clone())
                .keyed()
                .window_tumbling(64, |ts: &u64| *ts)
                .count_by_key()
                .run_to_close();
            let rows: Vec<(String, i64)> = out
                .windows
                .iter()
                .flat_map(|w| {
                    w.pairs
                        .iter()
                        .map(move |p| (format!("w{}:k{}", w.window, p.key), p.value))
                })
                .collect();
            digest_pairs(&rows)
        });

        ScenarioKit { plans, stream_plan }
    }

    /// The seeded per-driver plan assignment (public so a failing run's
    /// specs can be inspected when replaying a seed).
    pub fn specs(&self, sc: &Scenario) -> Vec<Vec<PlanSpec>> {
        let mut rng = Xoshiro256::seeded(sc.seed);
        (0..sc.drivers)
            .map(|_| {
                (0..sc.plans_per_driver)
                    .map(|_| {
                        let bench = self.plans[rng.below(self.plans.len() as u64) as usize].0;
                        let optimize = if rng.below(2) == 0 {
                            OptimizeMode::Auto
                        } else {
                            OptimizeMode::Off
                        };
                        let cached = rng.below(2) == 0;
                        let stream = rng.below(4) == 0;
                        let adaptive = rng.below(2) == 0;
                        PlanSpec {
                            bench,
                            optimize,
                            cached,
                            stream,
                            adaptive,
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Run one slot's plan against `rt` under `base` narrowed to the
    /// spec's knobs, returning the canonical result digest (public so
    /// repeat harnesses like [`run_adaptive_repeat`] can drive single
    /// slots).
    pub fn run_one(&self, rt: &Runtime, base: &JobConfig, spec: PlanSpec) -> u64 {
        let cfg = base
            .clone()
            .with_optimize(spec.optimize)
            .with_cache_enabled(spec.cached)
            .with_adaptive(spec.adaptive);
        if spec.stream {
            return (self.stream_plan)(rt, &cfg);
        }
        let plan = self
            .plans
            .iter()
            .find(|(b, _)| *b == spec.bench)
            .expect("catalog covers all seven benchmarks");
        (plan.1)(rt, &cfg)
    }
}

/// Run the scenario end to end (serial baselines, then the concurrent
/// phase, then the pair-for-pair comparison). `Err` carries a replayable
/// description including the seed.
pub fn run_scenario(kit: &ScenarioKit, sc: &Scenario) -> Result<(), String> {
    let specs = kit.specs(sc);
    let base = JobConfig::fast().with_threads(sc.threads.max(1));

    // Serial baselines: one plan at a time on a fresh session.
    let serial_rt = Runtime::with_config(base.clone());
    let baseline: Vec<Vec<u64>> = specs
        .iter()
        .map(|driver_specs| {
            driver_specs
                .iter()
                .map(|s| kit.run_one(&serial_rt, &base, *s))
                .collect()
        })
        .collect();

    // Concurrent phase: one fresh shared session, N drivers.
    let rt = Runtime::with_config(base.clone());
    let spawned_before = rt.spawned_threads();
    let concurrent: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|driver_specs| {
                let rt = &rt;
                let base = &base;
                scope.spawn(move || {
                    driver_specs
                        .iter()
                        .map(|s| kit.run_one(rt, base, *s))
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scenario driver panicked"))
            .collect()
    });

    if rt.spawned_threads() != spawned_before {
        return Err(format!(
            "session pool grew under concurrency: {} -> {} (replay with MR4R_SCENARIO_SEED={})",
            spawned_before,
            rt.spawned_threads(),
            sc.seed
        ));
    }
    if rt.pool().active_batches() != 0 {
        return Err(format!(
            "pool reports in-flight batches after all drivers joined \
             (replay with MR4R_SCENARIO_SEED={})",
            sc.seed
        ));
    }
    for (d, (base_digests, conc_digests)) in baseline.iter().zip(&concurrent).enumerate() {
        for (j, (serial, conc)) in base_digests.iter().zip(conc_digests).enumerate() {
            if serial != conc {
                let spec = specs[d][j];
                let what = if spec.stream {
                    "Streaming".to_string()
                } else {
                    format!("{:?}", spec.bench)
                };
                return Err(format!(
                    "driver {d} plan {j} ({what} under {:?}): concurrent digest {conc:#018x} \
                     != serial {serial:#018x} — replay with MR4R_SCENARIO_SEED={}",
                    spec.optimize, sc.seed
                ));
            }
        }
    }
    Ok(())
}

/// [`run_scenario`], panicking with the replay seed on failure — the test
/// entry point.
pub fn assert_scenario(kit: &ScenarioKit, sc: &Scenario) {
    if let Err(msg) = run_scenario(kit, sc) {
        panic!("concurrency scenario failed: {msg}");
    }
}

/// Run one seeded batch slot **twice** on a shared adaptive session and
/// once statically on a fresh one, checking the feedback loop's contract
/// end to end: the first run records statistics into the session
/// [`StatsStore`](crate::stats::StatsStore), the second lowering of the
/// identical prefix *consults* them, and neither the feedback nor any
/// rewrite it drives changes the result digest.
pub fn run_adaptive_repeat(kit: &ScenarioKit, seed: u64, threads: usize) -> Result<(), String> {
    let shape = Scenario {
        seed,
        drivers: 1,
        plans_per_driver: 1,
        threads,
    };
    let mut spec = kit.specs(&shape)[0][0];
    // Pin the knobs the check depends on — the seed still picks the
    // workload. Batch + Auto + uncached keeps prefix fingerprints purely
    // structural, so both runs land on identical store keys.
    spec.optimize = OptimizeMode::Auto;
    spec.cached = false;
    spec.stream = false;
    spec.adaptive = true;
    let base = JobConfig::fast().with_threads(threads.max(1));

    let rt = Runtime::with_config(base.clone());
    let first = kit.run_one(&rt, &base, spec);
    if rt.stats().records() == 0 {
        return Err(format!(
            "{:?}: first run recorded no statistics (replay with MR4R_SCENARIO_SEED={seed})",
            spec.bench
        ));
    }
    let consulted_before = rt.stats().consults();
    let second = kit.run_one(&rt, &base, spec);
    if rt.stats().consults() == consulted_before {
        return Err(format!(
            "{:?}: second lowering never consulted the statistics store \
             (replay with MR4R_SCENARIO_SEED={seed})",
            spec.bench
        ));
    }
    if first != second {
        return Err(format!(
            "{:?}: adapted repeat digest {second:#018x} != first run {first:#018x} \
             (replay with MR4R_SCENARIO_SEED={seed})",
            spec.bench
        ));
    }
    let static_rt = Runtime::with_config(base.clone());
    let baseline = kit.run_one(
        &static_rt,
        &base,
        PlanSpec {
            adaptive: false,
            ..spec
        },
    );
    if baseline != first {
        return Err(format!(
            "{:?}: adaptive digest {first:#018x} != static baseline {baseline:#018x} \
             (replay with MR4R_SCENARIO_SEED={seed})",
            spec.bench
        ));
    }
    Ok(())
}

/// [`run_adaptive_repeat`], panicking with the replay seed on failure —
/// the test entry point.
pub fn assert_adaptive_repeat(kit: &ScenarioKit, seed: u64, threads: usize) {
    if let Err(msg) = run_adaptive_repeat(kit, seed, threads) {
        panic!("adaptive repeat scenario failed: {msg}");
    }
}

/// Governed scenario shape: `drivers` OS threads, each driving
/// `tenants_per_driver` registered tenants × `plans_per_tenant` seeded
/// plans, all on one shared **governed** session
/// ([`crate::govern`]).
///
/// Tenant specs derive from the tenant index (see [`tenant_spec_for`]):
/// priority classes cycle Interactive → Batch → Background and weights
/// alternate 1/2, so the weighted scheduler sees a genuinely mixed
/// population; every fourth tenant is **over budget** — a 1-byte heap
/// budget on a live accounting heap plus a 0-byte cache budget, so its
/// first completed plan trips the feedback signal and every later
/// admission sees pressure. Over-budget tenants alternate the Defer and
/// Degrade overload policies (Reject would panic the plan; it gets its
/// own `try_collect` coverage).
///
/// The harness checks the governance invariants *and* that every digest
/// still matches an ungoverned serial baseline pair for pair:
/// governance may delay or de-optimize a tenant's plans, never change
/// their results.
#[derive(Clone, Copy, Debug)]
pub struct GovernedScenario {
    /// Master seed (same per-slot plan derivation as [`Scenario`]).
    pub seed: u64,
    pub drivers: usize,
    pub tenants_per_driver: usize,
    /// Plans per tenant — keep ≥ 2 so over-budget tenants trip their
    /// budget signal (plan 1 records the footprint plan 2's admission
    /// compares).
    pub plans_per_tenant: usize,
    /// Worker threads of the shared session pool.
    pub threads: usize,
}

/// Whether tenant `index` runs with the deliberately-unsatisfiable
/// budgets (see [`GovernedScenario`]).
pub fn over_budget(index: usize) -> bool {
    index % 4 == 0
}

/// The per-index tenant spec derivation — public so tests can
/// cross-check scoreboard rows against the spec that produced them.
pub fn tenant_spec_for(index: usize) -> TenantSpec {
    let classes = [Priority::Interactive, Priority::Batch, Priority::Background];
    let mut spec = TenantSpec::new(&format!("t{index:03}"))
        .with_priority(classes[index % classes.len()])
        .with_weight(1 + (index % 2) as u32);
    if over_budget(index) {
        let policy = if (index / 4) % 2 == 0 {
            OverloadPolicy::Defer
        } else {
            OverloadPolicy::Degrade
        };
        spec = spec
            .with_heap_budget(1)
            .with_cache_budget(0)
            .with_overload(policy);
    }
    spec
}

/// Run a governed scenario end to end: ungoverned serial baselines,
/// then the governed concurrent phase on a fresh session with every
/// tenant registered, then digest and scoreboard checks. `Err` carries
/// a replayable description including the seed.
pub fn run_governed_scenario(kit: &ScenarioKit, sc: &GovernedScenario) -> Result<(), String> {
    let n_tenants = sc.drivers * sc.tenants_per_driver;
    let shape = Scenario {
        seed: sc.seed,
        drivers: n_tenants,
        plans_per_driver: sc.plans_per_tenant,
        threads: sc.threads,
    };
    let mut specs = kit.specs(&shape);
    // Over-budget tenants must open with a *batch* plan: its epilogue
    // records the footprint later admissions compare (a streaming first
    // slot never reaches the job epilogue, leaving the signal unset).
    for (t, row) in specs.iter_mut().enumerate() {
        if over_budget(t) {
            if let Some(first) = row.first_mut() {
                first.stream = false;
            }
        }
    }
    let base = JobConfig::fast().with_threads(sc.threads.max(1));

    // Ungoverned serial baselines: the digests governance must not
    // change.
    let serial_rt = Runtime::with_config(base.clone());
    let baseline: Vec<Vec<u64>> = specs
        .iter()
        .map(|row| row.iter().map(|s| kit.run_one(&serial_rt, &base, *s)).collect())
        .collect();

    // Governed phase: a fresh shared session, every plan tagged with its
    // tenant's config. The tiny defer deadline keeps throttled tenants
    // moving (Defer admits after the deadline either way).
    let rt = Runtime::with_config(base.clone());
    rt.governor().set_defer_deadline(Duration::from_millis(2));
    let ids: Vec<TenantId> = (0..n_tenants)
        .map(|t| rt.register_tenant(tenant_spec_for(t)))
        .collect();
    let configs: Vec<JobConfig> = ids
        .iter()
        .enumerate()
        .map(|(t, &id)| {
            let cfg = rt.config_for(id);
            if over_budget(t) {
                // A live accounting heap (no wall-clock injection): the
                // budget signal is the job's measured cohort footprint.
                cfg.with_heap(SimHeap::new(HeapParams::no_injection()))
            } else {
                cfg
            }
        })
        .collect();

    let spawned_before = rt.spawned_threads();
    let concurrent: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..sc.drivers)
            .map(|d| {
                let rt = &rt;
                let specs = &specs;
                let configs = &configs;
                scope.spawn(move || {
                    let lo = d * sc.tenants_per_driver;
                    (lo..lo + sc.tenants_per_driver)
                        .map(|t| {
                            specs[t]
                                .iter()
                                .map(|s| kit.run_one(rt, &configs[t], *s))
                                .collect::<Vec<u64>>()
                        })
                        .collect::<Vec<Vec<u64>>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("governed driver panicked"))
            .collect()
    });

    if rt.spawned_threads() != spawned_before {
        return Err(format!(
            "session pool grew under governance: {} -> {} (replay with MR4R_SCENARIO_SEED={})",
            spawned_before,
            rt.spawned_threads(),
            sc.seed
        ));
    }
    for (t, (base_digests, gov_digests)) in baseline.iter().zip(&concurrent).enumerate() {
        for (j, (serial, gov)) in base_digests.iter().zip(gov_digests).enumerate() {
            if serial != gov {
                let spec = specs[t][j];
                let what = if spec.stream {
                    "Streaming".to_string()
                } else {
                    format!("{:?}", spec.bench)
                };
                return Err(format!(
                    "tenant {t} plan {j} ({what} under {:?}): governed digest {gov:#018x} \
                     != ungoverned serial {serial:#018x} — replay with MR4R_SCENARIO_SEED={}",
                    spec.optimize, sc.seed
                ));
            }
        }
    }

    let board = rt.scoreboard();
    let mut background_executed = 0u64;
    for (t, id) in ids.iter().enumerate() {
        let row = board
            .get(*id)
            .ok_or_else(|| format!("tenant {t} missing from the scoreboard"))?;
        if row.submitted == 0 || row.executed != row.submitted || row.queue_depth != 0 {
            return Err(format!(
                "tenant {t} lost work: {} executed of {} submitted, depth {} \
                 (replay with MR4R_SCENARIO_SEED={})",
                row.executed, row.submitted, row.queue_depth, sc.seed
            ));
        }
        if row.rejected != 0 {
            return Err(format!(
                "tenant {t} rejected {} time(s) under Defer/Degrade policies \
                 (replay with MR4R_SCENARIO_SEED={})",
                row.rejected, sc.seed
            ));
        }
        if row.priority == Priority::Background {
            background_executed += row.executed;
        }
        if over_budget(t) && sc.plans_per_tenant >= 2 {
            let throttled = row.deferred + row.degraded + row.ingest_deferred;
            if throttled == 0 {
                return Err(format!(
                    "over-budget tenant {t} was never throttled: admitted {}, \
                     last job {} B (replay with MR4R_SCENARIO_SEED={})",
                    row.admitted, row.heap_last_job_bytes, sc.seed
                ));
            }
        }
    }
    if n_tenants >= 3 && background_executed == 0 {
        return Err(format!(
            "Background tenants starved: 0 tasks executed (replay with MR4R_SCENARIO_SEED={})",
            sc.seed
        ));
    }
    Ok(())
}

/// [`run_governed_scenario`], panicking with the replay seed on failure
/// — the test entry point.
pub fn assert_governed_scenario(kit: &ScenarioKit, sc: &GovernedScenario) {
    if let Err(msg) = run_governed_scenario(kit, sc) {
        panic!("governed scenario failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_are_seed_deterministic() {
        let kit = ScenarioKit::prepare(0.0002, 7);
        let sc = Scenario {
            seed: 99,
            drivers: 3,
            plans_per_driver: 4,
            threads: 2,
        };
        let a = kit.specs(&sc);
        let b = kit.specs(&sc);
        assert_eq!(a, b, "same seed, same assignment");
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|d| d.len() == 4));
        assert!(
            (100..108).any(|seed| kit.specs(&Scenario { seed, ..sc }) != a),
            "assignment must depend on the seed"
        );
    }

    #[test]
    fn tiny_scenario_passes() {
        let kit = ScenarioKit::prepare(0.0002, 7);
        let sc = Scenario {
            seed: 11,
            drivers: 2,
            plans_per_driver: 2,
            threads: 2,
        };
        assert_scenario(&kit, &sc);
    }

    #[test]
    fn tenant_spec_derivation_is_mixed() {
        let classes: Vec<Priority> = (0..6).map(|i| tenant_spec_for(i).priority).collect();
        assert!(classes.contains(&Priority::Interactive));
        assert!(classes.contains(&Priority::Batch));
        assert!(classes.contains(&Priority::Background));
        assert!(over_budget(0) && !over_budget(1));
        assert_eq!(tenant_spec_for(0).heap_budget, Some(1));
        assert_eq!(tenant_spec_for(0).overload, OverloadPolicy::Defer);
        assert_eq!(tenant_spec_for(4).overload, OverloadPolicy::Degrade);
        assert_eq!(tenant_spec_for(1).heap_budget, None);
    }

    #[test]
    fn tiny_adaptive_repeat_passes() {
        let kit = ScenarioKit::prepare(0.0002, 7);
        assert_adaptive_repeat(&kit, scenario_seed(23), 2);
    }

    #[test]
    fn tiny_governed_scenario_passes() {
        let kit = ScenarioKit::prepare(0.0002, 7);
        let sc = GovernedScenario {
            seed: 13,
            drivers: 2,
            tenants_per_driver: 2,
            plans_per_tenant: 2,
            threads: 2,
        };
        assert_governed_scenario(&kit, &sc);
    }
}
