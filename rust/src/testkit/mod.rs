//! Test-support substrates.
//!
//! `proptest` is not in the offline vendor set, so [`prop`] provides a small
//! property-testing kit with seeded generation and greedy case minimization.
//! Used by the coordinator-invariant and optimizer-equivalence properties.

pub mod prop;
