//! Test-support substrates.
//!
//! * [`prop`] — a small property-testing kit with seeded generation and
//!   greedy case minimization (`proptest` is not in the offline vendor
//!   set). Used by the coordinator-invariant, optimizer-equivalence, and
//!   scheduler-fairness properties.
//! * [`scenario`] — the deterministic concurrency harness: seeded
//!   N-driver × M-plan runs over the seven benchmark workloads on one
//!   shared [`crate::api::Runtime`], checked pair-for-pair against serial
//!   execution.
//!
//! # Seed reproducibility — the replay workflow
//!
//! Both kits are driven by the crate PRNG and print their seed on
//! failure, so any red run is replayable exactly:
//!
//! 1. A failing property panics with `replay with MR4R_PROP_SEED=<seed>`;
//!    a failing scenario panics with `replay with
//!    MR4R_SCENARIO_SEED=<seed>`.
//! 2. Re-run just that test with the printed variable set, e.g.
//!    `MR4R_PROP_SEED=24150 cargo test -q failing_test_name` — the kit
//!    reads the variable ([`prop::check_with_shrink`],
//!    [`scenario::scenario_seed`]) and regenerates the identical case or
//!    plan assignment.
//! 3. `MR4R_PROP_CASES` optionally raises the case count when hunting
//!    flakiness; `MR4R_THREADS` (read by the concurrency suite in
//!    `rust/tests/concurrent_runtime.rs`) re-runs the same scenarios at a
//!    different worker-pool width.
//!
//! Scenario replays regenerate the same *plan assignment*; OS thread
//! interleaving stays nondeterministic by design — the invariant under
//! test is that results must not depend on it.

pub mod prop;
pub mod scenario;
