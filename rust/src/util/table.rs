//! Fixed-width text tables — the harness prints every reproduced figure and
//! table in this format so runs are directly comparable to the paper.

/// A simple column-aligned text table builder.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a separator line under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                // Right-align numeric-looking cells, left-align text.
                let cell = &cells[i];
                let numeric = cell
                    .chars()
                    .all(|c| c.is_ascii_digit() || ".-+eEx%".contains(c))
                    && !cell.is_empty();
                if numeric && i > 0 {
                    line.push_str(&format!("{:>width$}", cell, width = widths[i]));
                } else {
                    line.push_str(&format!("{:<width$}", cell, width = widths[i]));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting outside).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with 2 decimal places (the paper's precision for speedups).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a float with 3 decimal places.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format seconds as adaptive human units.
pub fn human_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(vec!["bench", "speedup"]);
        t.row(vec!["wordcount", "1.92"]);
        t.row(vec!["sm", "0.95"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("bench"));
        assert!(lines[2].contains("wordcount"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn wrong_width_panics() {
        let mut t = TextTable::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = TextTable::new(vec!["k", "v"]);
        t.row(vec!["a,b", "say \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn human_units() {
        assert_eq!(human_secs(2.5), "2.50s");
        assert_eq!(human_secs(0.0025), "2.50ms");
        assert_eq!(human_secs(0.0000025), "2.5us");
    }
}
