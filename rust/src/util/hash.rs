//! Fast non-cryptographic hashing for the collector hot path.
//!
//! The intermediate (key, value) collector hashes every emitted key once per
//! emit — for Word Count that is tens of millions of string hashes. The std
//! SipHash is DoS-resistant but ~3× slower than needed here; this is the
//! FxHash function (as used by rustc) plus a `BuildHasher` so it can plug
//! into `std::collections::HashMap` and our own sharded table.

use std::hash::{BuildHasherDefault, Hasher};

const SEED64: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: multiply-rotate word-at-a-time hasher (rustc's default).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED64);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

/// `BuildHasher` for plugging [`FxHasher`] into hash maps.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// A `HashMap` wired to FxHash.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// Hash a single value with FxHash (convenience for shard routing).
#[inline]
pub fn fxhash<T: std::hash::Hash>(v: &T) -> u64 {
    let mut h = FxHasher::default();
    v.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(fxhash(&"hello"), fxhash(&"hello"));
        assert_eq!(fxhash(&12345u64), fxhash(&12345u64));
    }

    #[test]
    fn distinct_inputs_rarely_collide() {
        use std::collections::HashSet;
        let hashes: HashSet<u64> = (0..10_000u64).map(|i| fxhash(&i)).collect();
        assert_eq!(hashes.len(), 10_000, "u64 inputs must not collide");
        let shashes: HashSet<u64> = (0..10_000u32)
            .map(|i| fxhash(&format!("key-{i}")))
            .collect();
        assert_eq!(shashes.len(), 10_000, "string inputs must not collide");
    }

    #[test]
    fn spreads_across_shards() {
        // Shard routing uses the high bits; check balance over 64 shards.
        let mut counts = [0usize; 64];
        for i in 0..64_000u64 {
            let h = fxhash(&format!("word{i}"));
            counts[(h >> 58) as usize] += 1;
        }
        let (min, max) = (
            counts.iter().min().copied().unwrap(),
            counts.iter().max().copied().unwrap(),
        );
        assert!(min > 700 && max < 1300, "imbalanced: min={min} max={max}");
    }

    #[test]
    fn works_in_hashmap() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        for i in 0..100 {
            m.insert(format!("k{i}"), i);
        }
        assert_eq!(m["k42"], 42);
    }
}
