//! Small self-contained substrates the rest of the crate builds on.
//!
//! The offline vendor set contains only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (clap, serde, rand, criterion)
//! are re-implemented here at the size this project actually needs:
//!
//! * [`prng`] — splitmix64 / xoshiro256** deterministic PRNGs (rand stand-in).
//! * [`timer`] — monotonic stopwatch + aggregate statistics.
//! * [`json`] — minimal JSON writer for machine-readable reports.
//! * [`cli`] — declarative flag parser for the `mr4r` binary (clap stand-in).
//! * [`hash`] — FxHash-style fast hasher used by the collector hot path.
//! * [`table`] — fixed-width text tables for figure/table output.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prng;
pub mod table;
pub mod timer;
