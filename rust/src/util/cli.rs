//! Declarative command-line flag parsing (clap is not in the vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated `--help` text. Just enough for the `mr4r`
//! launcher and the example binaries.

use std::collections::BTreeMap;

/// Specification of one flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// A tiny declarative CLI parser.
#[derive(Debug, Default)]
pub struct Cli {
    bin: &'static str,
    about: &'static str,
    flags: Vec<FlagSpec>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<&'static str, String>,
    bools: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

/// Error from parsing; `Help` means `--help` was requested.
#[derive(Debug)]
pub enum CliError {
    Help(String),
    UnknownFlag(String),
    MissingValue(&'static str),
    BadValue { flag: &'static str, msg: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Help(h) => write!(f, "{h}"),
            CliError::UnknownFlag(flag) => write!(f, "unknown flag `{flag}`"),
            CliError::MissingValue(flag) => write!(f, "flag `--{flag}` requires a value"),
            CliError::BadValue { flag, msg } => write!(f, "invalid value for `--{flag}`: {msg}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli {
            bin,
            about,
            flags: Vec::new(),
        }
    }

    /// Register a value-taking flag with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// Register a value-taking flag without a default (optional).
    pub fn opt_no_default(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    /// Register a boolean flag (defaults to false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Render the help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [FLAGS]\n\nFLAGS:\n", self.bin, self.about, self.bin);
        for f in &self.flags {
            let arg = if f.takes_value {
                format!("--{} <v>", f.name)
            } else {
                format!("--{}", f.name)
            };
            let def = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<24} {}{def}\n", f.help));
        }
        s.push_str("  --help                   show this message\n");
        s
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                args.values.insert(f.name, d.clone());
            }
            if !f.takes_value {
                args.bools.insert(f.name, false);
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help(self.help_text()));
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.to_string()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or(CliError::MissingValue(spec.name))?,
                    };
                    args.values.insert(spec.name, val);
                } else {
                    args.bools.insert(spec.name, true);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn parse_env(&self) -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&argv)
    }
}

impl Args {
    pub fn get(&self, name: &'static str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &'static str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Typed accessor: parse the flag's value via `FromStr`.
    pub fn parse_as<T: std::str::FromStr>(&self, name: &'static str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name).ok_or(CliError::MissingValue(name))?;
        raw.parse::<T>().map_err(|e| CliError::BadValue {
            flag: name,
            msg: format!("{e} (got `{raw}`)"),
        })
    }

    /// Typed accessor with an in-code fallback for optional flags.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &'static str, fallback: T) -> T {
        self.get(name)
            .and_then(|raw| raw.parse::<T>().ok())
            .unwrap_or(fallback)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("threads", "4", "thread count")
            .opt_no_default("scale", "input scale")
            .switch("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&sv(&[])).unwrap();
        assert_eq!(a.get("threads"), Some("4"));
        assert_eq!(a.get("scale"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cli()
            .parse(&sv(&["--threads", "8", "--scale=0.5", "--verbose"]))
            .unwrap();
        assert_eq!(a.parse_as::<usize>("threads").unwrap(), 8);
        assert_eq!(a.parse_as::<f64>("scale").unwrap(), 0.5);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse(&sv(&["fig5", "--threads", "2"])).unwrap();
        assert_eq!(a.positional(), &["fig5".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(
            cli().parse(&sv(&["--nope"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cli().parse(&sv(&["--threads"])),
            Err(CliError::MissingValue("threads"))
        ));
    }

    #[test]
    fn help_lists_flags() {
        let h = cli().help_text();
        assert!(h.contains("--threads"));
        assert!(h.contains("--verbose"));
        let err = cli().parse(&sv(&["--help"]));
        assert!(matches!(err, Err(CliError::Help(_))));
    }

    #[test]
    fn bad_value_reported() {
        let a = cli().parse(&sv(&["--threads", "zap"])).unwrap();
        assert!(a.parse_as::<usize>("threads").is_err());
        assert_eq!(a.parse_or::<usize>("threads", 7), 7);
    }
}
