//! Monotonic timing + summary statistics for the harness and benches.

use std::time::{Duration, Instant};

/// A simple stopwatch around `Instant`.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    /// Elapsed wall-clock time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed time in seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed time in milliseconds as f64.
    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Aggregate statistics over repeated measurements (the paper reports the
/// average of 10 runs with 5 warm-up iterations; [`Samples`] mirrors that).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    values: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { values: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn median(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

/// Geometric mean of a slice of ratios (used for cross-benchmark speedups,
/// matching how the paper summarizes Figure 6).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Run `f` with `warmup` discarded iterations then `iters` measured ones,
/// returning the measured samples in seconds. Mirrors the paper's protocol
/// ("executed ten times (Java includes a five iteration warm-up)").
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        s.push(sw.secs());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.millis() >= 4.0);
    }

    #[test]
    fn samples_stats() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn median_odd() {
        let mut s = Samples::new();
        for v in [9.0, 1.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.median(), 5.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn measure_counts_iters() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let s = measure(2, 3, || {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 5);
        assert_eq!(s.len(), 3);
    }
}
