//! Deterministic pseudo-random number generation.
//!
//! `rand` is not in the offline vendor set, so the data generators and the
//! property-testing kit use these small, well-known generators instead.
//! Everything in the repo that consumes randomness takes an explicit seed so
//! benchmark inputs and property-test cases are reproducible run-to-run.

/// splitmix64 — used to seed the main generator and for cheap one-shot
/// hashing of seeds. Reference: Steele, Lea, Flood (2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — the workhorse generator (Blackman & Vigna).
/// Fast, 256-bit state, passes BigCrush; more than adequate for synthetic
/// dataset generation and property-test case selection.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (fixed point of xoshiro).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Xoshiro256 { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        // Widening multiply; rejection keeps the distribution exactly uniform.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (used by the k-means cluster generator).
    pub fn normal(&mut self) -> f64 {
        // Draw until u > 0 so ln() is finite.
        let mut u = self.unit_f64();
        while u <= f64::MIN_POSITIVE {
            u = self.unit_f64();
        }
        let v = self.unit_f64();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fork a statistically independent child generator (for per-thread or
    /// per-shard streams derived from one master seed).
    pub fn fork(&mut self) -> Xoshiro256 {
        Xoshiro256::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "streams should differ: {same} collisions");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Xoshiro256::seeded(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Xoshiro256::seeded(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all 8 buckets hit");
    }

    #[test]
    fn unit_f64_in_range_and_spread() {
        let mut r = Xoshiro256::seeded(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Xoshiro256::seeded(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Xoshiro256::seeded(13);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
