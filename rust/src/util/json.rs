//! Minimal JSON document builder (serde is not in the offline vendor set).
//!
//! Only what the report writer needs: objects, arrays, strings, numbers,
//! booleans. Output is deterministic (insertion order preserved) so report
//! files diff cleanly between runs.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert (or append) a key into an object. Panics on non-objects —
    /// report-building bugs should fail loudly in tests.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Append to an array. Panics on non-arrays.
    pub fn push(&mut self, val: impl Into<Json>) {
        match self {
            Json::Arr(items) => items.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let close = "  ".repeat(indent);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    it.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        // JSON has no NaN/Inf; encode as null like most writers do.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(
            Json::Str("a\"b\\c\nd".into()).to_string(),
            r#""a\"b\\c\nd""#
        );
    }

    #[test]
    fn nested_document() {
        let mut arr = Json::arr();
        arr.push(1u64);
        arr.push(2u64);
        let doc = Json::obj()
            .set("name", "wc")
            .set("speedup", 1.9)
            .set("threads", arr);
        assert_eq!(
            doc.to_string(),
            r#"{"name":"wc","speedup":1.9,"threads":[1,2]}"#
        );
    }

    #[test]
    fn pretty_roundtrip_shape() {
        let doc = Json::obj().set("a", 1u64).set("b", Json::arr());
        let p = doc.pretty();
        assert!(p.contains("\"a\": 1"));
        assert!(p.contains("\"b\": []"));
    }
}
