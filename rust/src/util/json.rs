//! Minimal JSON document builder and parser (serde is not in the
//! offline vendor set).
//!
//! Only what the report writer and the observability round-trip tests
//! need: objects, arrays, strings, numbers, booleans. Output is
//! deterministic (insertion order preserved) so report files diff
//! cleanly between runs; [`Json::parse`] reads the same dialect back
//! (full JSON, including `\uXXXX` escapes and surrogate pairs).

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert (or append) a key into an object. Panics on non-objects —
    /// report-building bugs should fail loudly in tests.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Append to an array. Panics on non-arrays.
    pub fn push(&mut self, val: impl Into<Json>) {
        match self {
            Json::Arr(items) => items.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
    }

    /// Parse a JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(value)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value as an integer (rounds toward zero).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let close = "  ".repeat(indent);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    it.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}", pos = *pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => expect(bytes, pos, "null").map(|_| Json::Null),
        Some(b't') => expect(bytes, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => expect(bytes, pos, "false").map(|_| Json::Bool(false)),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, ":")?;
                let value = parse_value(bytes, pos)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(_) => parse_number(bytes, pos).map(Json::Num),
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<f64, String> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    std::str::from_utf8(&bytes[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    if bytes.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                let esc = *bytes.get(*pos).ok_or("unterminated escape")?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut unit = parse_hex4(bytes, pos)?;
                        // Surrogate pair: combine the low half.
                        if (0xD800..0xDC00).contains(&unit) && bytes[*pos..].starts_with(b"\\u") {
                            let save = *pos;
                            *pos += 2;
                            let low = parse_hex4(bytes, pos)?;
                            if (0xDC00..0xE000).contains(&low) {
                                let c = 0x10000
                                    + ((unit as u32 - 0xD800) << 10)
                                    + (low as u32 - 0xDC00);
                                out.push(char::from_u32(c).ok_or("bad surrogate pair")?);
                                continue;
                            }
                            *pos = save;
                            unit = 0xFFFD; // lone high surrogate
                        }
                        out.push(char::from_u32(unit as u32).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape `\\{}`", other as char)),
                }
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so slicing
                // at char boundaries is safe).
                let rest = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| "invalid utf-8".to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u16, String> {
    let end = *pos + 4;
    if end > bytes.len() {
        return Err("truncated \\u escape".to_string());
    }
    let hex = std::str::from_utf8(&bytes[*pos..end]).map_err(|_| "bad \\u escape")?;
    let v = u16::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
    *pos = end;
    Ok(v)
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        // JSON has no NaN/Inf; encode as null like most writers do.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(
            Json::Str("a\"b\\c\nd".into()).to_string(),
            r#""a\"b\\c\nd""#
        );
    }

    #[test]
    fn nested_document() {
        let mut arr = Json::arr();
        arr.push(1u64);
        arr.push(2u64);
        let doc = Json::obj()
            .set("name", "wc")
            .set("speedup", 1.9)
            .set("threads", arr);
        assert_eq!(
            doc.to_string(),
            r#"{"name":"wc","speedup":1.9,"threads":[1,2]}"#
        );
    }

    #[test]
    fn pretty_roundtrip_shape() {
        let doc = Json::obj().set("a", 1u64).set("b", Json::arr());
        let p = doc.pretty();
        assert!(p.contains("\"a\": 1"));
        assert!(p.contains("\"b\": []"));
    }

    #[test]
    fn parse_round_trips_builder_output() {
        let mut arr = Json::arr();
        arr.push(1u64);
        arr.push(Json::Null);
        let doc = Json::obj()
            .set("name", "wc \"quoted\"\n")
            .set("speedup", 1.9)
            .set("neg", -3i64)
            .set("ok", true)
            .set("items", arr)
            .set("empty", Json::obj());
        for text in [doc.to_string(), doc.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), doc, "failed on {text}");
        }
    }

    #[test]
    fn parse_accessors_walk_documents() {
        let doc = Json::parse(r#"{"tenants":[{"name":"a","executed":7}],"n":2.5}"#).unwrap();
        let tenants = doc.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(tenants[0].get("executed").unwrap().as_u64(), Some(7));
        assert_eq!(doc.get("n").unwrap().as_f64(), Some(2.5));
        assert!(doc.get("missing").is_none());
    }

    #[test]
    fn parse_handles_escapes_and_unicode() {
        let doc = Json::parse("\"a\\u0041\\t\\ud83d\\ude00é\"").unwrap();
        assert_eq!(doc.as_str(), Some("aA\t😀é"));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }
}
