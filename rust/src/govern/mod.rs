//! Tenant governance: admission control, QoS budgets, and the live
//! scoreboard.
//!
//! PR 4 made concurrent plans on one [`Runtime`](crate::api::Runtime)
//! *fair* (tagged-batch round-robin); this module makes them *governed*.
//! A tenant is registered once per session
//! ([`Runtime::register_tenant`](crate::api::Runtime::register_tenant))
//! with a [`TenantSpec`] — priority class, worker-share weight, simulated
//! heap budget, cache byte budget, and an overload policy — and from then
//! on every job, plan stage, cache entry, and standing query that runs
//! under a tenant-tagged [`JobConfig`](crate::api::config::JobConfig) is
//! attributed to it:
//!
//! * **QoS scheduling.** The tenant's priority-class multiplier × weight
//!   becomes its submissions' weighted-round-robin quota in the session
//!   pool's pick loop (deficit round-robin — see
//!   [`crate::coordinator::scheduler`]). Higher classes are served more
//!   picks per credit round; lower classes are *preempted by not being
//!   picked*, never descheduled mid-task, and never starved (credits
//!   refresh whenever every runnable submission is out of credit).
//! * **Admission control.** Each plan collect passes an admission gate
//!   before anything executes. Pressure is detected from the framework's
//!   own signals: the tenant's **previous job's exact simulated-heap
//!   footprint** versus its byte budget, and global [`SimHeap`] occupancy
//!   versus a watermark. The tenant's [`OverloadPolicy`] decides what an
//!   over-pressure submission does: hard-reject, defer with a deadline,
//!   or degrade (run with the optimizer forced off — results are
//!   rewrite-independent, so this trades speed for admission, never
//!   correctness).
//! * **Scoreboard.** Every counter here is a relaxed atomic bumped on
//!   paths that already hold the relevant lock or own the data, so
//!   [`Runtime::scoreboard`](crate::api::Runtime::scoreboard) snapshots
//!   the whole session mid-flight without stopping the pool.
//!
//! # How budgets map onto `SimHeap` cohorts
//!
//! The heap budget is *not* a reservation. Every job already charges its
//! allocations to scoped cohorts on the session's simulated heap
//! (`job.scratch`, `job.results`, collector cohorts — see
//! [`crate::coordinator::pipeline`] and [`crate::memsim`]), and the job
//! epilogue reads the exact per-cohort `(bytes, objects)` attribution
//! before releasing them. Governance piggybacks on that attribution: the
//! epilogue stores the job's total cohort bytes as the tenant's
//! `heap_last_job_bytes`, and the *next* admission for the same tenant
//! compares that exact figure against [`TenantSpec::heap_budget`]. A
//! tenant whose last job overran its budget is therefore throttled on its
//! next submission — feedback control on measured footprint, not a guess
//! made before the job runs. Cache budgets work the same way against the
//! bytes the cache layer charges to its `cache.entry` cohorts: an insert
//! that would push the tenant's live cached bytes past
//! [`TenantSpec::cache_budget`] is denied (the plan keeps its computed
//! value; nothing is stored) and counted.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::coordinator::scheduler::QosCounters;
use crate::memsim::SimHeap;

/// Identifies a registered tenant within one
/// [`Runtime`](crate::api::Runtime) session (dense, assigned by
/// [`Governor::register`] in registration order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u64);

/// QoS priority class: the coarse tier of a tenant's scheduling share.
/// The class multiplier scales the tenant's weighted-round-robin quota
/// (`multiplier × weight`), so an Interactive tenant with weight 1 is
/// served four picks per credit round for every one pick of a Background
/// tenant — and Background still progresses every round (deficit
/// round-robin never starves).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive serving traffic (multiplier 4).
    Interactive,
    /// Ordinary analytics (multiplier 2) — the default class.
    Batch,
    /// Best-effort backfill (multiplier 1).
    Background,
}

impl Priority {
    /// The quota multiplier this class contributes.
    pub fn multiplier(self) -> u32 {
        match self {
            Priority::Interactive => 4,
            Priority::Batch => 2,
            Priority::Background => 1,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

/// What an over-pressure submission does at the admission gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Refuse the submission outright:
    /// [`Dataset::try_collect`](crate::api::plan::Dataset::try_collect)
    /// returns [`AdmissionError`] (and plain `collect()` panics). Nothing
    /// runs; the rejection is counted on the scoreboard.
    Reject,
    /// Queue with a deadline: poll until the pressure clears or the
    /// governor's defer deadline ([`Governor::set_defer_deadline`])
    /// elapses, then admit either way — work is *delayed*, never lost.
    Defer,
    /// Admit immediately but force the tenant's jobs to run with the
    /// optimizer off until a clean admission clears the latch. Rewrites
    /// never change results (the equivalence suites pin that), so this
    /// sheds optimizer speed, not correctness — the cheapest pressure
    /// valve.
    Degrade,
}

/// A tenant's registration: identity, QoS class, and budgets. Budgets
/// left `None` are unlimited in that dimension.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Human-readable name (scoreboard rows, error messages).
    pub name: String,
    /// QoS priority class (default [`Priority::Batch`]).
    pub priority: Priority,
    /// Worker-share weight within the class (≥ 1; default 1). The
    /// effective scheduler quota is `priority.multiplier() × weight`.
    pub weight: u32,
    /// Simulated-heap byte budget per job: admission pressure triggers
    /// when the tenant's previous job allocated more cohort bytes than
    /// this (see the module docs for the cohort mapping).
    pub heap_budget: Option<u64>,
    /// Cap on the tenant's live materialization-cache bytes: inserts
    /// that would exceed it are denied (computed value kept, entry not
    /// stored) and counted as `cache_denials`.
    pub cache_budget: Option<u64>,
    /// What happens when admission detects pressure (default
    /// [`OverloadPolicy::Defer`]).
    pub overload: OverloadPolicy,
}

impl TenantSpec {
    /// A spec with defaults: Batch class, weight 1, unlimited budgets,
    /// Defer on overload.
    pub fn new(name: &str) -> Self {
        TenantSpec {
            name: name.to_string(),
            priority: Priority::Batch,
            weight: 1,
            heap_budget: None,
            cache_budget: None,
            overload: OverloadPolicy::Defer,
        }
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_weight(mut self, weight: u32) -> Self {
        self.weight = weight.max(1);
        self
    }

    pub fn with_heap_budget(mut self, bytes: u64) -> Self {
        self.heap_budget = Some(bytes);
        self
    }

    pub fn with_cache_budget(mut self, bytes: u64) -> Self {
        self.cache_budget = Some(bytes);
        self
    }

    pub fn with_overload(mut self, policy: OverloadPolicy) -> Self {
        self.overload = policy;
        self
    }
}

/// The non-scheduler half of a tenant's live counters (the scheduler half
/// is [`QosCounters`]). All relaxed atomics: each is bumped by exactly
/// one logical writer at a time (the tenant's own job epilogue, admission
/// gate, or cache insert), and the scoreboard tolerates torn cross-field
/// reads — it is a monitoring surface, not a ledger.
#[derive(Debug, Default)]
pub struct TenantCounters {
    /// Jobs (eager jobs and plan stages) completed under this tenant.
    pub jobs_completed: AtomicU64,
    /// Total simulated-heap cohort bytes attributed across all jobs.
    pub heap_allocated_bytes: AtomicU64,
    /// Total simulated-heap objects attributed across all jobs.
    pub heap_allocated_objects: AtomicU64,
    /// Exact cohort bytes of the most recent completed job — the budget
    /// signal the next admission compares (see module docs).
    pub heap_last_job_bytes: AtomicU64,
    /// Admissions that went through (clean, deferred, or degraded).
    pub admitted: AtomicU64,
    /// Hard rejections ([`OverloadPolicy::Reject`] under pressure).
    pub rejected: AtomicU64,
    /// Admissions that waited at the gate ([`OverloadPolicy::Defer`]).
    pub deferred: AtomicU64,
    /// Total milliseconds spent waiting at the defer gate.
    pub defer_wait_ms: AtomicU64,
    /// Admissions that set the degrade latch
    /// ([`OverloadPolicy::Degrade`] under pressure).
    pub degraded: AtomicU64,
    /// Cache inserts denied by the tenant's cache byte budget.
    pub cache_denials: AtomicU64,
    /// Live materialization-cache bytes currently charged to this tenant
    /// (inserts add, evictions/removals subtract).
    pub cache_live_bytes: AtomicU64,
    /// Total cache bytes released from this tenant's entries (evictions,
    /// explicit removals, session clears).
    pub cache_evicted_bytes: AtomicU64,
    /// Bytes this tenant currently holds in the cache's cold spill tier
    /// (spills add, reloads and cold-tier drops subtract). Counts
    /// against `cache_budget` together with `cache_live_bytes`.
    pub cache_spill_bytes: AtomicU64,
    /// Producer pushes that blocked on this tenant's bounded streams.
    pub stream_pushes_blocked: AtomicU64,
    /// Producer `try_push` calls shed by this tenant's bounded streams.
    pub stream_pushes_shed: AtomicU64,
    /// Standing-query chunk ingests delayed at the backpressure gate.
    /// Stream ingest never *drops* data — dropping would break digest
    /// identity with serial baselines — so Reject-policy tenants are
    /// deferred here too.
    pub ingest_deferred: AtomicU64,
    /// Adaptive re-optimization decisions applied to this tenant's plans
    /// (filter reorders, shard resizes, flow switches, hot-key splits —
    /// see [`AdaptationReport`](crate::stats::AdaptationReport)).
    pub adaptations: AtomicU64,
    /// Degrade latch: while set, the tenant's jobs run with the
    /// optimizer forced off (the config layer consults it when choosing
    /// the execution flow); cleared by the next clean admission.
    degrade: AtomicBool,
}

/// One registered tenant: its spec plus every live counter surface. The
/// runtime hands `Arc<TenantHandle>`s into job configs, batches, cache
/// entries, and standing queries, so attribution costs one pointer per
/// object and counter bumps are uncontended relaxed atomics.
#[derive(Debug)]
pub struct TenantHandle {
    id: TenantId,
    spec: TenantSpec,
    qos: Arc<QosCounters>,
    counters: TenantCounters,
}

impl TenantHandle {
    pub fn id(&self) -> TenantId {
        self.id
    }

    pub fn spec(&self) -> &TenantSpec {
        &self.spec
    }

    /// The scheduler-side counters (shared with every batch this tenant
    /// opens).
    pub fn qos(&self) -> &Arc<QosCounters> {
        &self.qos
    }

    pub fn counters(&self) -> &TenantCounters {
        &self.counters
    }

    /// The weighted-round-robin quota this tenant's submissions carry:
    /// priority-class multiplier × weight.
    pub fn quota(&self) -> u32 {
        self.spec
            .priority
            .multiplier()
            .saturating_mul(self.spec.weight.max(1))
    }

    /// Whether the degrade latch is set (jobs run optimizer-off).
    pub(crate) fn degraded(&self) -> bool {
        self.counters.degrade.load(Ordering::Relaxed)
    }

    /// Job-epilogue attribution: one completed job's exact cohort
    /// footprint.
    pub(crate) fn note_job(&self, alloc_bytes: u64, alloc_objects: u64) {
        self.counters.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.counters
            .heap_allocated_bytes
            .fetch_add(alloc_bytes, Ordering::Relaxed);
        self.counters
            .heap_allocated_objects
            .fetch_add(alloc_objects, Ordering::Relaxed);
        self.counters
            .heap_last_job_bytes
            .store(alloc_bytes, Ordering::Relaxed);
    }
}

/// How an admitted submission got through the gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// No pressure: admitted immediately (clears any degrade latch).
    Clean,
    /// Pressure under [`OverloadPolicy::Defer`]: admitted after waiting
    /// at the gate (until clear or deadline).
    Deferred,
    /// Pressure under [`OverloadPolicy::Degrade`]: admitted with the
    /// optimizer forced off.
    Degraded,
}

/// A hard admission rejection ([`OverloadPolicy::Reject`] under
/// pressure). Returned by
/// [`Dataset::try_collect`](crate::api::plan::Dataset::try_collect);
/// plain `collect()` panics with it.
#[derive(Clone, Debug)]
pub struct AdmissionError {
    pub tenant: TenantId,
    /// The tenant's registered name.
    pub name: String,
    /// Which pressure signal fired.
    pub reason: String,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "tenant `{}` ({:?}) not admitted: {}",
            self.name, self.tenant, self.reason
        )
    }
}

impl std::error::Error for AdmissionError {}

/// Per-plan governance accounting, attached to
/// [`PlanReport`](crate::api::plan::PlanReport) when the plan ran under a
/// tenant. For streaming outputs `admission` is [`Admission::Clean`]:
/// streaming admission acts per-ingest at the backpressure gate, and its
/// outcomes land on the scoreboard, not here.
#[derive(Clone, Debug)]
pub struct GovernReport {
    pub tenant: TenantId,
    pub name: String,
    pub priority: Priority,
    /// The weighted-round-robin quota the plan's batches carried.
    pub quota: u32,
    pub admission: Admission,
}

/// The session governor a [`Runtime`](crate::api::Runtime) owns: the
/// tenant registry plus the admission knobs. Registration is append-only
/// (`TenantId`s are dense indices); lookups clone an `Arc`, and the
/// steady-state read path takes the registry `RwLock` only for reads.
#[derive(Debug)]
pub struct Governor {
    tenants: RwLock<Vec<Arc<TenantHandle>>>,
    /// Global heap-occupancy fraction at which admission sees pressure.
    watermark: RwLock<f64>,
    /// How long a [`OverloadPolicy::Defer`] admission may wait at the
    /// gate before being admitted anyway.
    defer_deadline: RwLock<Duration>,
}

impl Governor {
    pub(crate) fn new() -> Self {
        Governor {
            tenants: RwLock::new(Vec::new()),
            watermark: RwLock::new(0.9),
            defer_deadline: RwLock::new(Duration::from_millis(25)),
        }
    }

    /// Register a tenant; the returned id tags job configs
    /// ([`JobConfig::with_tenant`](crate::api::config::JobConfig::with_tenant),
    /// [`Runtime::config_for`](crate::api::Runtime::config_for)).
    pub fn register(&self, spec: TenantSpec) -> TenantId {
        let mut tenants = self.tenants.write().unwrap();
        let id = TenantId(tenants.len() as u64);
        tenants.push(Arc::new(TenantHandle {
            id,
            spec,
            qos: Arc::new(QosCounters::default()),
            counters: TenantCounters::default(),
        }));
        id
    }

    /// The handle for a registered tenant, if any.
    pub fn lookup(&self, id: TenantId) -> Option<Arc<TenantHandle>> {
        self.tenants
            .read()
            .unwrap()
            .get(id.0 as usize)
            .map(Arc::clone)
    }

    /// Number of registered tenants.
    pub fn tenant_count(&self) -> usize {
        self.tenants.read().unwrap().len()
    }

    /// True when no tenant is registered — the session is ungoverned and
    /// every code path behaves exactly as before this subsystem existed.
    pub fn is_empty(&self) -> bool {
        self.tenant_count() == 0
    }

    /// Set the global heap-occupancy pressure watermark (fraction of the
    /// heap's `total_bytes`; clamped to `0.0..=1.0`; default 0.9).
    pub fn set_watermark(&self, watermark: f64) {
        *self.watermark.write().unwrap() = watermark.clamp(0.0, 1.0);
    }

    /// Set how long Defer-policy admissions wait at the gate before
    /// being admitted anyway (default 25 ms; soak tests shrink it).
    pub fn set_defer_deadline(&self, deadline: Duration) {
        *self.defer_deadline.write().unwrap() = deadline;
    }

    /// The pressure signal, if any: tenant heap budget exceeded by the
    /// previous job's exact footprint, or global heap occupancy at/over
    /// the watermark.
    fn pressure(&self, tenant: &TenantHandle, heap: &SimHeap) -> Option<String> {
        if let Some(budget) = tenant.spec.heap_budget {
            let last = tenant.counters.heap_last_job_bytes.load(Ordering::Relaxed);
            if last > budget {
                return Some(format!(
                    "heap budget exceeded: previous job allocated {last} B of a {budget} B budget"
                ));
            }
        }
        let watermark = *self.watermark.read().unwrap();
        let occupancy = heap.occupancy();
        if occupancy >= watermark {
            return Some(format!(
                "heap occupancy {:.0}% at/over the {:.0}% watermark",
                occupancy * 100.0,
                watermark * 100.0
            ));
        }
        None
    }

    /// The admission gate for one job-shaped submission (a plan
    /// collect). Applies the tenant's [`OverloadPolicy`] under pressure;
    /// a clean admission clears the degrade latch.
    pub(crate) fn admit_job(
        &self,
        tenant: &Arc<TenantHandle>,
        heap: &SimHeap,
    ) -> Result<Admission, AdmissionError> {
        let Some(reason) = self.pressure(tenant, heap) else {
            tenant.counters.degrade.store(false, Ordering::Relaxed);
            tenant.counters.admitted.fetch_add(1, Ordering::Relaxed);
            return Ok(Admission::Clean);
        };
        match tenant.spec.overload {
            OverloadPolicy::Reject => {
                tenant.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(AdmissionError {
                    tenant: tenant.id,
                    name: tenant.spec.name.clone(),
                    reason,
                })
            }
            OverloadPolicy::Defer => {
                let deadline = *self.defer_deadline.read().unwrap();
                let start = Instant::now();
                while start.elapsed() < deadline {
                    std::thread::sleep(Duration::from_millis(1));
                    if self.pressure(tenant, heap).is_none() {
                        break;
                    }
                }
                tenant.counters.deferred.fetch_add(1, Ordering::Relaxed);
                tenant
                    .counters
                    .defer_wait_ms
                    .fetch_add(start.elapsed().as_millis() as u64, Ordering::Relaxed);
                tenant.counters.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(Admission::Deferred)
            }
            OverloadPolicy::Degrade => {
                tenant.counters.degrade.store(true, Ordering::Relaxed);
                tenant.counters.degraded.fetch_add(1, Ordering::Relaxed);
                tenant.counters.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(Admission::Degraded)
            }
        }
    }

    /// The streaming backpressure gate: under pressure, delay the ingest
    /// (up to the defer deadline) but never drop it — dropping would
    /// break digest identity with serial baselines, so Reject-policy
    /// tenants are deferred here too. Counted as `ingest_deferred`.
    pub(crate) fn gate_ingest(&self, tenant: &Arc<TenantHandle>, heap: &SimHeap) {
        if self.pressure(tenant, heap).is_none() {
            return;
        }
        tenant
            .counters
            .ingest_deferred
            .fetch_add(1, Ordering::Relaxed);
        let deadline = *self.defer_deadline.read().unwrap();
        let start = Instant::now();
        while start.elapsed() < deadline {
            std::thread::sleep(Duration::from_millis(1));
            if self.pressure(tenant, heap).is_none() {
                break;
            }
        }
    }

    /// Snapshot every tenant's counters mid-flight (no pool pause; see
    /// [`TenantCounters`] for the consistency contract).
    pub fn scoreboard(&self) -> Scoreboard {
        let tenants = self.tenants.read().unwrap();
        Scoreboard {
            tenants: tenants.iter().map(|t| TenantSnapshot::of(t)).collect(),
            metrics: None,
        }
    }
}

/// One tenant's row on the [`Scoreboard`]: spec identity plus every
/// counter, read at snapshot time.
#[derive(Clone, Debug)]
pub struct TenantSnapshot {
    pub id: TenantId,
    pub name: String,
    pub priority: Priority,
    pub weight: u32,
    /// Effective weighted-round-robin quota (multiplier × weight).
    pub quota: u32,
    /// Scheduler: tasks submitted under this tenant's batches.
    pub submitted: u64,
    /// Scheduler: tasks finished.
    pub executed: u64,
    /// Scheduler: tasks taken from a sibling worker's deque.
    pub steals: u64,
    /// Scheduler: picks skipped while out of round-robin credit.
    pub preempted: u64,
    /// Scheduler: tasks submitted but not yet finished (queued or
    /// running) at snapshot time.
    pub queue_depth: u64,
    pub jobs_completed: u64,
    pub heap_allocated_bytes: u64,
    pub heap_allocated_objects: u64,
    pub heap_last_job_bytes: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub deferred: u64,
    pub defer_wait_ms: u64,
    pub degraded: u64,
    pub cache_denials: u64,
    pub cache_live_bytes: u64,
    pub cache_evicted_bytes: u64,
    pub cache_spill_bytes: u64,
    pub stream_pushes_blocked: u64,
    pub stream_pushes_shed: u64,
    pub ingest_deferred: u64,
    /// Adaptive re-optimization decisions applied to this tenant's plans.
    pub adaptations: u64,
}

impl TenantSnapshot {
    fn of(t: &TenantHandle) -> TenantSnapshot {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let submitted = load(&t.qos.submitted);
        let executed = load(&t.qos.executed);
        TenantSnapshot {
            id: t.id,
            name: t.spec.name.clone(),
            priority: t.spec.priority,
            weight: t.spec.weight,
            quota: t.quota(),
            submitted,
            executed,
            steals: load(&t.qos.steals),
            preempted: load(&t.qos.preempted),
            queue_depth: submitted.saturating_sub(executed),
            jobs_completed: load(&t.counters.jobs_completed),
            heap_allocated_bytes: load(&t.counters.heap_allocated_bytes),
            heap_allocated_objects: load(&t.counters.heap_allocated_objects),
            heap_last_job_bytes: load(&t.counters.heap_last_job_bytes),
            admitted: load(&t.counters.admitted),
            rejected: load(&t.counters.rejected),
            deferred: load(&t.counters.deferred),
            defer_wait_ms: load(&t.counters.defer_wait_ms),
            degraded: load(&t.counters.degraded),
            cache_denials: load(&t.counters.cache_denials),
            cache_live_bytes: load(&t.counters.cache_live_bytes),
            cache_evicted_bytes: load(&t.counters.cache_evicted_bytes),
            cache_spill_bytes: load(&t.counters.cache_spill_bytes),
            stream_pushes_blocked: load(&t.counters.stream_pushes_blocked),
            stream_pushes_shed: load(&t.counters.stream_pushes_shed),
            ingest_deferred: load(&t.counters.ingest_deferred),
            adaptations: load(&t.counters.adaptations),
        }
    }
}

/// A mid-flight snapshot of every tenant's counters
/// ([`Runtime::scoreboard`](crate::api::Runtime::scoreboard)).
#[derive(Clone, Debug)]
pub struct Scoreboard {
    /// One row per registered tenant, in registration (id) order.
    pub tenants: Vec<TenantSnapshot>,
    /// The session metrics registry at snapshot time
    /// ([`Runtime::metrics`](crate::api::Runtime::metrics)) — filled by
    /// the runtime wrapper; `None` when the scoreboard came straight
    /// from [`Governor::scoreboard`].
    pub metrics: Option<crate::trace::MetricsSnapshot>,
}

impl Scoreboard {
    pub fn get(&self, id: TenantId) -> Option<&TenantSnapshot> {
        self.tenants.get(id.0 as usize)
    }

    /// Attach a session metrics snapshot, surfaced as the `metrics`
    /// object in [`Scoreboard::snapshot_json`].
    pub fn with_metrics(mut self, metrics: crate::trace::MetricsSnapshot) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Render the scoreboard as a fixed-width text table (the `mr4r
    /// govern` CLI output).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<4} {:<16} {:<12} {:>5} {:>9} {:>9} {:>6} {:>8} {:>8} {:>4} {:>4} {:>4} {:>6} {:>12}",
            "id",
            "tenant",
            "class",
            "quota",
            "executed",
            "submitted",
            "steal",
            "preempt",
            "adm",
            "rej",
            "def",
            "deg",
            "deny$",
            "heap B",
        );
        for t in &self.tenants {
            let _ = writeln!(
                out,
                "{:<4} {:<16} {:<12} {:>5} {:>9} {:>9} {:>6} {:>8} {:>8} {:>4} {:>4} {:>4} {:>6} {:>12}",
                t.id.0,
                t.name,
                t.priority.label(),
                t.quota,
                t.executed,
                t.submitted,
                t.steals,
                t.preempted,
                t.admitted,
                t.rejected,
                t.deferred,
                t.degraded,
                t.cache_denials,
                t.heap_allocated_bytes,
            );
        }
        out
    }

    /// Serialize the scoreboard as a JSON document (the `mr4r govern
    /// --json` CLI output) — one object per tenant, every snapshot
    /// field, deterministic key order, so the output is scriptable and
    /// diffs cleanly between polls.
    pub fn snapshot_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut rows = Json::arr();
        for t in &self.tenants {
            rows.push(
                Json::obj()
                    .set("id", t.id.0)
                    .set("name", t.name.as_str())
                    .set("priority", t.priority.label())
                    .set("weight", t.weight)
                    .set("quota", t.quota)
                    .set("submitted", t.submitted)
                    .set("executed", t.executed)
                    .set("steals", t.steals)
                    .set("preempted", t.preempted)
                    .set("queue_depth", t.queue_depth)
                    .set("jobs_completed", t.jobs_completed)
                    .set("heap_allocated_bytes", t.heap_allocated_bytes)
                    .set("heap_allocated_objects", t.heap_allocated_objects)
                    .set("heap_last_job_bytes", t.heap_last_job_bytes)
                    .set("admitted", t.admitted)
                    .set("rejected", t.rejected)
                    .set("deferred", t.deferred)
                    .set("defer_wait_ms", t.defer_wait_ms)
                    .set("degraded", t.degraded)
                    .set("cache_denials", t.cache_denials)
                    .set("cache_live_bytes", t.cache_live_bytes)
                    .set("cache_evicted_bytes", t.cache_evicted_bytes)
                    .set("cache_spill_bytes", t.cache_spill_bytes)
                    .set("stream_pushes_blocked", t.stream_pushes_blocked)
                    .set("stream_pushes_shed", t.stream_pushes_shed)
                    .set("ingest_deferred", t.ingest_deferred)
                    .set("adaptations", t.adaptations),
            );
        }
        let mut doc = Json::obj().set("tenants", rows);
        if let Some(metrics) = &self.metrics {
            doc = doc.set("metrics", metrics.to_json());
        }
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{HeapParams, SimHeap};

    fn heap() -> Arc<SimHeap> {
        SimHeap::new(HeapParams::no_injection())
    }

    #[test]
    fn quota_is_class_multiplier_times_weight() {
        let g = Governor::new();
        let interactive =
            g.register(TenantSpec::new("i").with_priority(Priority::Interactive).with_weight(3));
        let background =
            g.register(TenantSpec::new("b").with_priority(Priority::Background));
        assert_eq!(g.lookup(interactive).unwrap().quota(), 12);
        assert_eq!(g.lookup(background).unwrap().quota(), 1);
        // Weight clamps at the builder, so quota is never 0.
        let clamped = g.register(TenantSpec::new("c").with_weight(0));
        assert_eq!(g.lookup(clamped).unwrap().quota(), 2);
    }

    #[test]
    fn clean_admission_counts_and_clears_latch() {
        let g = Governor::new();
        let id = g.register(TenantSpec::new("t"));
        let t = g.lookup(id).unwrap();
        t.counters.degrade.store(true, Ordering::Relaxed);
        let heap = heap();
        assert_eq!(g.admit_job(&t, &heap).unwrap(), Admission::Clean);
        assert!(!t.degraded(), "clean admission clears the degrade latch");
        assert_eq!(t.counters.admitted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn reject_policy_errors_under_budget_pressure() {
        let g = Governor::new();
        let id = g.register(
            TenantSpec::new("hog")
                .with_heap_budget(10)
                .with_overload(OverloadPolicy::Reject),
        );
        let t = g.lookup(id).unwrap();
        let heap = heap();
        // Under budget: clean.
        assert!(g.admit_job(&t, &heap).is_ok());
        // The "previous job" overran the budget → hard reject.
        t.note_job(100, 5);
        let err = g.admit_job(&t, &heap).unwrap_err();
        assert_eq!(err.tenant, id);
        assert!(err.reason.contains("heap budget"), "{}", err.reason);
        assert_eq!(t.counters.rejected.load(Ordering::Relaxed), 1);
        assert!(err.to_string().contains("hog"));
    }

    #[test]
    fn defer_policy_waits_then_admits() {
        let g = Governor::new();
        g.set_defer_deadline(Duration::from_millis(2));
        let id = g.register(TenantSpec::new("slow").with_heap_budget(1));
        let t = g.lookup(id).unwrap();
        t.note_job(50, 1);
        let heap = heap();
        assert_eq!(g.admit_job(&t, &heap).unwrap(), Admission::Deferred);
        assert_eq!(t.counters.deferred.load(Ordering::Relaxed), 1);
        assert_eq!(t.counters.admitted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn degrade_policy_sets_latch_until_clean() {
        let g = Governor::new();
        let id = g.register(
            TenantSpec::new("soft")
                .with_heap_budget(1)
                .with_overload(OverloadPolicy::Degrade),
        );
        let t = g.lookup(id).unwrap();
        t.note_job(50, 1);
        let heap = heap();
        assert_eq!(g.admit_job(&t, &heap).unwrap(), Admission::Degraded);
        assert!(t.degraded());
        // A small job clears the pressure; the next admission is clean
        // and lifts the latch.
        t.note_job(0, 0);
        assert_eq!(g.admit_job(&t, &heap).unwrap(), Admission::Clean);
        assert!(!t.degraded());
    }

    #[test]
    fn scoreboard_snapshots_counters_mid_flight() {
        let g = Governor::new();
        let a = g.register(TenantSpec::new("a").with_priority(Priority::Interactive));
        let b = g.register(TenantSpec::new("b"));
        let ta = g.lookup(a).unwrap();
        ta.qos.submitted.fetch_add(10, Ordering::Relaxed);
        ta.qos.executed.fetch_add(7, Ordering::Relaxed);
        ta.note_job(4096, 32);
        let board = g.scoreboard();
        assert_eq!(board.tenants.len(), 2);
        let row = board.get(a).unwrap();
        assert_eq!(row.quota, 4);
        assert_eq!(row.queue_depth, 3);
        assert_eq!(row.heap_last_job_bytes, 4096);
        assert_eq!(board.get(b).unwrap().submitted, 0);
        let text = board.render();
        assert!(text.contains("interactive"), "{text}");
        assert!(text.contains('a'), "{text}");
    }

    #[test]
    fn scoreboard_json_mirrors_snapshot_fields() {
        let g = Governor::new();
        let a = g.register(TenantSpec::new("alpha").with_priority(Priority::Interactive));
        let ta = g.lookup(a).unwrap();
        ta.qos.submitted.fetch_add(5, Ordering::Relaxed);
        ta.qos.executed.fetch_add(5, Ordering::Relaxed);
        ta.counters.adaptations.fetch_add(3, Ordering::Relaxed);
        let json = g.scoreboard().snapshot_json().to_string();
        assert!(json.contains("\"name\":\"alpha\""), "{json}");
        assert!(json.contains("\"priority\":\"interactive\""), "{json}");
        assert!(json.contains("\"executed\":5"), "{json}");
        assert!(json.contains("\"adaptations\":3"), "{json}");
        // Deterministic key order: tenants array leads the document.
        assert!(json.starts_with("{\"tenants\":["), "{json}");
    }

    #[test]
    fn scoreboard_json_round_trips_through_the_parser() {
        use crate::util::json::Json;
        let g = Governor::new();
        let a = g.register(TenantSpec::new("alpha").with_priority(Priority::Interactive));
        let _b = g.register(TenantSpec::new("beta").with_priority(Priority::Background));
        let ta = g.lookup(a).unwrap();
        ta.qos.submitted.fetch_add(7, Ordering::Relaxed);
        ta.counters.cache_spill_bytes.fetch_add(4096, Ordering::Relaxed);
        ta.counters.adaptations.fetch_add(2, Ordering::Relaxed);

        let registry = crate::trace::MetricsRegistry::new();
        registry.counter("plans.completed").add(3);
        registry.histogram("pool.task_us").record(250);

        let doc = g.scoreboard().with_metrics(registry.snapshot()).snapshot_json();
        let parsed = Json::parse(&doc.to_string()).expect("snapshot_json must emit valid JSON");

        let tenants = parsed.get("tenants").and_then(Json::as_arr).expect("tenants array");
        assert_eq!(tenants.len(), 2);
        let alpha = &tenants[0];
        assert_eq!(alpha.get("id").and_then(Json::as_u64), Some(a.0));
        assert_eq!(alpha.get("name").and_then(Json::as_str), Some("alpha"));
        assert_eq!(alpha.get("submitted").and_then(Json::as_u64), Some(7));
        assert_eq!(alpha.get("cache_spill_bytes").and_then(Json::as_u64), Some(4096));
        assert_eq!(alpha.get("adaptations").and_then(Json::as_u64), Some(2));
        let beta = &tenants[1];
        assert_eq!(beta.get("name").and_then(Json::as_str), Some("beta"));
        assert_eq!(beta.get("cache_spill_bytes").and_then(Json::as_u64), Some(0));
        assert_eq!(beta.get("adaptations").and_then(Json::as_u64), Some(0));

        let metrics = parsed.get("metrics").expect("metrics block when attached");
        assert_eq!(metrics.get("plans.completed").and_then(Json::as_u64), Some(3));
        let hist = metrics.get("pool.task_us").expect("histogram entry");
        assert_eq!(hist.get("count").and_then(Json::as_u64), Some(1));
        assert!(hist.get("p95").and_then(Json::as_u64).is_some());

        // Without an attached session snapshot the metrics key is absent
        // (a governor-only scoreboard stays exactly the legacy shape).
        let bare = Json::parse(&g.scoreboard().snapshot_json().to_string()).unwrap();
        assert!(bare.get("metrics").is_none());
    }

    #[test]
    fn ingest_gate_defers_but_never_rejects() {
        let g = Governor::new();
        g.set_defer_deadline(Duration::from_millis(2));
        let id = g.register(
            TenantSpec::new("s")
                .with_heap_budget(1)
                .with_overload(OverloadPolicy::Reject),
        );
        let t = g.lookup(id).unwrap();
        t.note_job(9, 1);
        let heap = heap();
        // Reject-policy tenant at the *ingest* gate: delayed, not refused.
        g.gate_ingest(&t, &heap);
        assert_eq!(t.counters.ingest_deferred.load(Ordering::Relaxed), 1);
        assert_eq!(t.counters.rejected.load(Ordering::Relaxed), 0);
    }
}
