//! Phoenix++ 1.0-like baseline.
//!
//! The key ideas from Talbot et al. the paper's evaluation leans on:
//!
//! * **Containers + combiners are the framework**: map emits go straight
//!   into a per-thread *container* that applies a *combiner object* inline
//!   — value lists never exist, so there is no allocation per emit and no
//!   reduce phase over lists.
//! * **Container choice is compile-time**: a `HashContainer` for sparse
//!   keys, an `ArrayContainer` for dense integer key spaces (histogram
//!   bins, matrix cells). Picking wrong (or needing a new one) requires
//!   understanding the framework internals — the programmability cost the
//!   paper weighs against MR4J's transparency (§2.3).
//! * **Merge is cheap**: per-thread containers hold one combined value per
//!   key, so the cross-thread merge touches `threads × keys` values, not
//!   `values` — this is why Phoenix++ scales where Phoenix dies.

use std::hash::Hash;
use std::sync::Mutex;

use crate::coordinator::scheduler::TaskPool;
use crate::coordinator::splitter::split_indices;
use crate::util::hash::FxHashMap;

/// A combiner object: associative fold with an identity (Phoenix++'s
/// `sum_combiner`, `one_combiner`, ... family).
pub trait CombineOp<V>: Sync {
    fn identity(&self) -> V;
    fn combine(&self, acc: &mut V, v: V);
}

/// Addition combiner over numeric values.
#[derive(Clone, Copy, Debug, Default)]
pub struct SumOp;

impl CombineOp<i64> for SumOp {
    fn identity(&self) -> i64 {
        0
    }
    fn combine(&self, acc: &mut i64, v: i64) {
        *acc += v;
    }
}

impl CombineOp<f64> for SumOp {
    fn identity(&self) -> f64 {
        0.0
    }
    fn combine(&self, acc: &mut f64, v: f64) {
        *acc += v;
    }
}

impl CombineOp<Vec<f64>> for SumOp {
    fn identity(&self) -> Vec<f64> {
        Vec::new()
    }
    fn combine(&self, acc: &mut Vec<f64>, v: Vec<f64>) {
        if acc.is_empty() {
            *acc = v;
        } else {
            debug_assert_eq!(acc.len(), v.len());
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
    }
}

/// A per-thread intermediate store keyed by `K`.
pub trait Container<K, V>: Send {
    fn update(&mut self, k: K, v: V, op: &dyn CombineOp<V>);
    /// Drain into (key, combined value) pairs.
    fn drain(self: Box<Self>) -> Vec<(K, V)>;
}

/// Sparse keys → hashed container (Phoenix++ `hash_container`).
pub struct HashContainer<K, V> {
    map: FxHashMap<K, V>,
}

impl<K, V> Default for HashContainer<K, V> {
    fn default() -> Self {
        HashContainer {
            map: FxHashMap::default(),
        }
    }
}

impl<K: Hash + Eq + Send, V: Send> Container<K, V> for HashContainer<K, V> {
    fn update(&mut self, k: K, v: V, op: &dyn CombineOp<V>) {
        match self.map.get_mut(&k) {
            Some(acc) => op.combine(acc, v),
            None => {
                let mut acc = op.identity();
                op.combine(&mut acc, v);
                self.map.insert(k, acc);
            }
        }
    }

    fn drain(self: Box<Self>) -> Vec<(K, V)> {
        self.map.into_iter().collect()
    }
}

/// Dense integer keys `0..n` → flat array container (Phoenix++
/// `array_container`; the histogram/matrix choice). The key-space bound is
/// fixed at construction — the compile-time tuning the paper criticizes
/// ("some configurations require tuning at compile time restricting the
/// data size at runtime").
pub struct ArrayContainer<V> {
    slots: Vec<Option<V>>,
}

impl<V> ArrayContainer<V> {
    pub fn new(key_space: usize) -> Self {
        ArrayContainer {
            slots: (0..key_space).map(|_| None).collect(),
        }
    }
}

impl<V: Send> Container<usize, V> for ArrayContainer<V> {
    fn update(&mut self, k: usize, v: V, op: &dyn CombineOp<V>) {
        // Out-of-range keys are a programming error in Phoenix++ (fixed
        // container bounds); fail loudly like the original's assert.
        let slot = &mut self.slots[k];
        match slot {
            Some(acc) => op.combine(acc, v),
            None => {
                let mut acc = op.identity();
                op.combine(&mut acc, v);
                *slot = Some(acc);
            }
        }
    }

    fn drain(self: Box<Self>) -> Vec<(usize, V)> {
        self.slots
            .into_iter()
            .enumerate()
            .filter_map(|(k, v)| v.map(|v| (k, v)))
            .collect()
    }
}

/// A Phoenix++ job: the container factory is the benchmark author's
/// compile-time choice; the combiner object runs inline at emit time.
pub struct PppJob<'a, I, K, V> {
    pub map: &'a (dyn Fn(&I, &mut dyn FnMut(K, V)) + Sync),
    pub combiner: &'a dyn CombineOp<V>,
    /// Per-thread container factory.
    pub container: &'a (dyn Fn() -> Box<dyn Container<K, V>> + Sync),
    /// Optional final transform (Phoenix++ benchmarks post-process in
    /// `main`, e.g. K-Means normalization).
    pub finalize: Option<&'a (dyn Fn(&K, V) -> V + Sync)>,
}

impl<I, K, V> PppJob<'_, I, K, V>
where
    I: Sync,
    K: Hash + Eq + Clone + Send + Sync,
    V: Send + Sync,
{
    pub fn run(&self, inputs: &[I], threads: usize) -> Vec<(K, V)> {
        let pool = TaskPool::new(threads.max(1));

        // ---- Map phase: per-thread containers with inline combining ----
        let ranges = split_indices(inputs.len(), threads.max(1));
        let drained: Vec<Mutex<Vec<(K, V)>>> =
            (0..ranges.len()).map(|_| Mutex::new(Vec::new())).collect();
        let tasks: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(tid, range)| {
                let drained = &drained;
                move |_wid: usize| {
                    let mut container = (self.container)();
                    for input in &inputs[range] {
                        (self.map)(input, &mut |k: K, v: V| {
                            container.update(k, v, self.combiner);
                        });
                    }
                    *drained[tid].lock().unwrap() = container.drain();
                }
            })
            .collect();
        pool.run(tasks);

        // ---- Merge: threads × keys combined values (cheap) ----
        let mut merged: FxHashMap<K, V> = FxHashMap::default();
        for cell in drained {
            for (k, v) in cell.into_inner().unwrap() {
                match merged.get_mut(&k) {
                    Some(acc) => {
                        // Merge via the same combiner (associativity).
                        self.combiner.combine(acc, v);
                    }
                    None => {
                        merged.insert(k, v);
                    }
                }
            }
        }

        // ---- Finalize ----
        match self.finalize {
            Some(f) => merged.into_iter().map(|(k, v)| {
                let v = f(&k, v);
                (k, v)
            }).collect(),
            None => merged.into_iter().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wc_map(line: &String, emit: &mut dyn FnMut(String, i64)) {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    }

    fn sorted<K: Ord, V>(mut v: Vec<(K, V)>) -> Vec<(K, V)> {
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    #[test]
    fn hash_container_word_count() {
        let job = PppJob {
            map: &wc_map,
            combiner: &SumOp,
            container: &|| {
                Box::new(HashContainer::<String, i64>::default())
                    as Box<dyn Container<String, i64>>
            },
            finalize: None,
        };
        let out = job.run(
            &["a b a".to_string(), "b a c".to_string()],
            4,
        );
        let out = sorted(out);
        assert_eq!(
            out,
            vec![
                ("a".to_string(), 3),
                ("b".to_string(), 2),
                ("c".to_string(), 1)
            ]
        );
    }

    #[test]
    fn array_container_histogram() {
        // Dense keys 0..8: the Phoenix++ histogram formulation.
        let bytes: Vec<u8> = (0..10_000u32).map(|i| (i % 8) as u8).collect();
        let chunks: Vec<&[u8]> = bytes.chunks(100).collect();
        let map = |chunk: &&[u8], emit: &mut dyn FnMut(usize, i64)| {
            for &b in chunk.iter() {
                emit(b as usize, 1);
            }
        };
        let job = PppJob {
            map: &map,
            combiner: &SumOp,
            container: &|| Box::new(ArrayContainer::<i64>::new(8)) as Box<dyn Container<usize, i64>>,
            finalize: None,
        };
        let out = sorted(job.run(&chunks, 3));
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|&(_, c)| c == 1250));
    }

    #[test]
    fn finalize_transforms_output() {
        let map = |x: &i64, emit: &mut dyn FnMut(i64, f64)| emit(*x % 2, *x as f64);
        let fin = |_k: &i64, v: f64| v / 10.0;
        let job = PppJob {
            map: &map,
            combiner: &SumOp,
            container: &|| {
                Box::new(HashContainer::<i64, f64>::default()) as Box<dyn Container<i64, f64>>
            },
            finalize: Some(&fin),
        };
        let out = sorted(job.run(&[1, 2, 3, 4], 2));
        assert_eq!(out, vec![(0, 0.6), (1, 0.4)]);
    }

    #[test]
    fn vector_sum_combiner() {
        let op = SumOp;
        let mut acc: Vec<f64> = op.identity();
        op.combine(&mut acc, vec![1.0, 2.0]);
        op.combine(&mut acc, vec![3.0, 4.0]);
        assert_eq!(acc, vec![4.0, 6.0]);
    }

    #[test]
    fn thread_counts_agree() {
        let bytes: Vec<u8> = (0..5000u32).map(|i| (i * 7 % 16) as u8).collect();
        let chunks: Vec<&[u8]> = bytes.chunks(64).collect();
        let map = |chunk: &&[u8], emit: &mut dyn FnMut(usize, i64)| {
            for &b in chunk.iter() {
                emit(b as usize, 1);
            }
        };
        let job = PppJob {
            map: &map,
            combiner: &SumOp,
            container: &|| Box::new(ArrayContainer::<i64>::new(16)) as Box<dyn Container<usize, i64>>,
            finalize: None,
        };
        let seq = sorted(job.run(&chunks, 1));
        let par = sorted(job.run(&chunks, 8));
        assert_eq!(seq, par);
    }
}
