//! Comparator runtimes — re-implementations of the two frameworks the
//! paper evaluates MR4J against (§2.2.2, §4):
//!
//! * [`phoenix`] — Phoenix 2.0-like (Yoo et al., C): per-thread keyval
//!   tables holding *value arrays*, an explicit cross-thread **merge
//!   phase**, then a parallel reduce phase. Optional manual combiner
//!   function (the user-written optimization the paper's §2.3 criticizes
//!   for duplicating code).
//! * [`phoenixpp`] — Phoenix++ 1.0-like (Talbot et al., C++): modular
//!   *container/combiner* design — per-thread containers combine values
//!   **inline at emit time** (never materializing value lists), with a
//!   cheap per-key merge. Container choice (hash vs fixed-size array) is a
//!   compile-time decision of the benchmark author, mirroring the
//!   "intimate understanding of the internal workings" the paper notes
//!   Phoenix++ demands.
//!
//! Neither baseline touches the memsim: they model *unmanaged* (C/C++)
//! memory, which is precisely the asymmetry the paper studies — MR4J pays
//! the GC, Phoenix/Phoenix++ pay their framework-structural costs (merge
//! passes, rigid containers).

pub mod phoenix;
pub mod phoenixpp;

pub use phoenix::{PhoenixConfig, PhoenixJob};
pub use phoenixpp::{ArrayContainer, CombineOp, Container, HashContainer, PppJob, SumOp};
