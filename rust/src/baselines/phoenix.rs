//! Phoenix 2.0-like baseline.
//!
//! Faithful to the design points the paper contrasts against (§2.2.2,
//! §2.3):
//!
//! * map workers write into **per-thread** keyval tables ("the collection
//!   of intermediate (key, value) pairs is local to each worker thread"),
//!   each key holding a growable value array;
//! * an optional **manual combiner** supplied by the user collapses a
//!   key's value buffer once it reaches a small threshold — Phoenix's
//!   hand-written optimization, duplicated application code and all;
//! * after the map phase, a **merge phase** consolidates the per-thread
//!   tables into a global table (an extra pass over every surviving value;
//!   this structural cost is what collapses Phoenix at high thread counts
//!   — paper: 0.20× of Phoenix++ at 64 threads);
//! * a parallel reduce phase over the merged table.

use std::hash::Hash;
use std::sync::Mutex;

use crate::coordinator::scheduler::TaskPool;
use crate::coordinator::splitter::split_indices;
use crate::util::hash::FxHashMap;

/// Hardware-specific manual tuning Phoenix demands (paper §4.1.2:
/// "configured manually using hardware specific parameters").
#[derive(Clone, Debug)]
pub struct PhoenixConfig {
    pub threads: usize,
    /// Items per map sub-chunk, derived from L1 cache size in the real
    /// framework.
    pub chunk_items: usize,
    /// Value-buffer length at which the manual combiner (if any) collapses
    /// a key's values ("incrementally combines intermediate values in a
    /// small buffer").
    pub combine_threshold: usize,
}

impl PhoenixConfig {
    pub fn new(threads: usize) -> Self {
        PhoenixConfig {
            threads: threads.max(1),
            chunk_items: 1024,
            combine_threshold: 8,
        }
    }
}

/// A Phoenix job. `reduce` collapses a value list to a single value
/// (Phoenix's API yields one value per key); `combiner` is the optional
/// manual optimization.
pub struct PhoenixJob<'a, I, K, V> {
    pub map: &'a (dyn Fn(&I, &mut dyn FnMut(K, V)) + Sync),
    pub reduce: &'a (dyn Fn(&K, &[V]) -> V + Sync),
    /// Manual combiner: fold `b` into `a`.
    pub combiner: Option<&'a (dyn Fn(&mut V, &V) + Sync)>,
}

impl<I, K, V> PhoenixJob<'_, I, K, V>
where
    I: Sync,
    K: Hash + Eq + Clone + Send + Sync,
    V: Clone + Send + Sync,
{
    /// Execute map → merge → reduce.
    pub fn run(&self, inputs: &[I], cfg: &PhoenixConfig) -> Vec<(K, V)> {
        let pool = TaskPool::new(cfg.threads);

        // ---- Map phase: one table per map task (≙ per worker thread) ----
        let ranges = split_indices(inputs.len(), cfg.threads);
        let n_tables = ranges.len();
        let tables: Vec<Mutex<FxHashMap<K, Vec<V>>>> =
            (0..n_tables).map(|_| Mutex::new(FxHashMap::default())).collect();
        let tasks: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(tid, range)| {
                let tables = &tables;
                move |_wid: usize| {
                    let mut local: FxHashMap<K, Vec<V>> = FxHashMap::default();
                    for input in &inputs[range] {
                        (self.map)(input, &mut |k: K, v: V| {
                            let list = local.entry(k).or_default();
                            list.push(v);
                            if let Some(comb) = self.combiner {
                                if list.len() >= 8 {
                                    // Collapse the buffer to one value —
                                    // Phoenix's manual combining.
                                    let (first, rest) = list.split_first_mut().unwrap();
                                    for r in rest.iter() {
                                        comb(first, r);
                                    }
                                    list.truncate(1);
                                }
                            }
                        });
                    }
                    *tables[tid].lock().unwrap() = local;
                }
            })
            .collect();
        pool.run(tasks);
        let thread_tables: Vec<FxHashMap<K, Vec<V>>> =
            tables.into_iter().map(|m| m.into_inner().unwrap()).collect();

        // ---- Merge phase ----
        // Phoenix's merge workers consolidate per-thread tables; every
        // surviving value is moved again. Sequential fold here (the real
        // framework's merge tree also serializes at the root), so merge
        // cost grows with thread count × key spread — the NUMA-unfriendly
        // part of the design.
        let mut merged: FxHashMap<K, Vec<V>> = FxHashMap::default();
        for table in thread_tables {
            for (k, mut vs) in table {
                merged.entry(k).or_default().append(&mut vs);
            }
        }

        // ---- Reduce phase ----
        let entries: Vec<(K, Vec<V>)> = merged.into_iter().collect();
        let out: Mutex<Vec<(K, V)>> = Mutex::new(Vec::new());
        let ranges = split_indices(entries.len(), cfg.threads * 4);
        let tasks: Vec<_> = ranges
            .into_iter()
            .map(|range| {
                let entries = &entries;
                let out = &out;
                move |_wid: usize| {
                    let mut local = Vec::with_capacity(range.len());
                    for (k, vs) in &entries[range] {
                        local.push((k.clone(), (self.reduce)(k, vs)));
                    }
                    out.lock().unwrap().extend(local);
                }
            })
            .collect();
        pool.run(tasks);
        out.into_inner().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wc_map(line: &String, emit: &mut dyn FnMut(String, i64)) {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    }

    fn sum_reduce(_k: &String, vs: &[i64]) -> i64 {
        vs.iter().sum()
    }

    fn inputs() -> Vec<String> {
        vec![
            "a b a c".to_string(),
            "b a".to_string(),
            "c c c".to_string(),
        ]
    }

    fn sorted(mut v: Vec<(String, i64)>) -> Vec<(String, i64)> {
        v.sort();
        v
    }

    #[test]
    fn word_count_without_combiner() {
        let job = PhoenixJob {
            map: &wc_map,
            reduce: &sum_reduce,
            combiner: None,
        };
        let out = job.run(&inputs(), &PhoenixConfig::new(2));
        assert_eq!(
            sorted(out),
            vec![
                ("a".to_string(), 3),
                ("b".to_string(), 2),
                ("c".to_string(), 4)
            ]
        );
    }

    #[test]
    fn manual_combiner_gives_same_answer() {
        let job_plain = PhoenixJob {
            map: &wc_map,
            reduce: &sum_reduce,
            combiner: None,
        };
        let comb = |a: &mut i64, b: &i64| *a += *b;
        let job_comb = PhoenixJob {
            map: &wc_map,
            reduce: &sum_reduce,
            combiner: Some(&comb),
        };
        // Enough repeats to cross the combine threshold.
        let big: Vec<String> = (0..100).map(|_| "x y x".to_string()).collect();
        let a = sorted(job_plain.run(&big, &PhoenixConfig::new(3)));
        let b = sorted(job_comb.run(&big, &PhoenixConfig::new(3)));
        assert_eq!(a, b);
        assert_eq!(a[0], ("x".to_string(), 200));
    }

    #[test]
    fn single_thread_matches_parallel() {
        let job = PhoenixJob {
            map: &wc_map,
            reduce: &sum_reduce,
            combiner: None,
        };
        let big: Vec<String> = (0..50)
            .map(|i| format!("w{} w{} shared", i % 7, i % 3))
            .collect();
        let seq = sorted(job.run(&big, &PhoenixConfig::new(1)));
        let par = sorted(job.run(&big, &PhoenixConfig::new(8)));
        assert_eq!(seq, par);
    }

    #[test]
    fn empty_input() {
        let job = PhoenixJob {
            map: &wc_map,
            reduce: &sum_reduce,
            combiner: None,
        };
        assert!(job.run(&[], &PhoenixConfig::new(4)).is_empty());
    }
}
