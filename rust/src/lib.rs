//! # MR4R — MapReduce for Rust, with a co-designed semantic optimizer
//!
//! A reproduction of *"Towards co-designed optimizations in parallel
//! frameworks: A MapReduce case study"* (Barrett, Kotselidis, Luján, 2016)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper introduces MR4J, a lightweight shared-memory MapReduce framework,
//! plus a *semantically aware* optimizer that transparently rewrites the user's
//! `reduce` method into a combiner (`initialize`/`combine`/`finalize`) applied
//! at emit time, eliminating the reduce phase and most intermediate-value
//! allocation. This crate is the L3 coordinator of the reproduction:
//!
//! * [`api`] — the public Mapper/Reducer/Emitter surface (paper Fig. 2),
//!   plus the session layer: [`api::Runtime`] owns a persistent worker
//!   pool, a shared optimizer agent, and the simulated heap; jobs are
//!   built with [`api::JobBuilder`], fed from any [`api::InputSource`]
//!   (slices, vectors, streaming chunk generators, previous job outputs),
//!   and chained/iterated through [`api::Runtime::pipeline`]. The lazy
//!   dataflow surface, [`api::plan::Dataset`], records whole multi-stage
//!   plans and executes them through the whole-plan optimizer (fusion +
//!   shard streaming) at `collect()` time; its keyed view
//!   ([`api::keyed`]) adds the declared-semantics aggregation algebra
//!   (`reduce_by_key`/`aggregate_by_key`/`join`) beside the inferred RIR
//!   channel.
//! * [`coordinator`] — work-stealing scheduler (batch + persistent pools),
//!   input splitter, sharded intermediate collector, and the two
//!   execution flows (reduce vs combine).
//! * [`cache`] — the plan-aware materialization cache: structural prefix
//!   fingerprints (computed by the planner during lowering), cross-plan
//!   subplan reuse at [`api::plan::Dataset::cache`] cut points with
//!   in-flight deduplication, and pressure-aware eviction accounted
//!   against the simulated heap.
//! * [`govern`] — multi-tenant governance: a tenant registry with QoS
//!   priority classes and weighted scheduler quotas, budget-keyed
//!   admission control (reject / defer / degrade-to-Off), streaming
//!   backpressure, and a live per-tenant [`govern::Scoreboard`]
//!   ([`api::Runtime::scoreboard`]).
//! * [`stats`] — adaptive re-optimization: a per-prefix-fingerprint
//!   [`stats::StatsStore`] owned by the [`api::Runtime`]. Every plan
//!   execution records measured cardinalities, filter selectivities,
//!   holder growth, and a key-frequency sketch; the next lowering of the
//!   same structural prefix consults them to reorder filters, right-size
//!   shard counts, switch keyed flows, and split hot keys — each decision
//!   reported in [`PlanReport::adaptation`].
//! * [`trace`] — the unified observability layer: a session-wide
//!   [`trace::Tracer`] recording spans from every subsystem (lowering,
//!   admission, batch/task scheduling, cache traffic, streaming panes,
//!   simulated GC) into per-thread lock-free ring buffers, exported as
//!   Chrome `trace_event` JSON (`mr4r trace <preset>`), plus the
//!   [`trace::MetricsRegistry`] of named counters/gauges/histograms
//!   surfaced by [`api::Runtime::metrics`] and the scoreboard.
//! * [`optimizer`] — the paper's §3 contribution: reducers expressed in a
//!   stack-machine IR (RIR, the bytecode stand-in), analyzed via a program
//!   dependency graph and sliced into `initialize`/`combine`/`finalize`.
//! * [`memsim`] — a generational managed-heap simulator standing in for the
//!   JVM GC, reproducing the allocation-lifetime mechanism behind Figs. 8–10.
//! * [`stream`] — continuous dataflow over unbounded sources: standing
//!   queries ([`api::Runtime::stream`]) with event-time tumbling/sliding
//!   windows whose panes reuse the declared aggregation holders (merged
//!   across overlapping windows instead of recomputed), plus incremental
//!   delta maintenance of cached [`api::plan::Dataset::cache`] prefixes
//!   over append-only sources ([`stream::AppendLog`]).
//! * [`baselines`] — Phoenix- and Phoenix++-like comparator runtimes.
//! * [`benchmarks`] — the seven-benchmark suite (Table 2) with scaled
//!   synthetic data generators.
//! * [`runtime`] — PJRT loader executing the AOT-compiled JAX/Pallas kernels
//!   (`artifacts/*.hlo.txt`) from the map phase; Python never runs at
//!   request time.
//! * [`harness`] — regenerates every table and figure in the evaluation.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod api;
pub mod baselines;
pub mod benchmarks;
pub mod cache;
pub mod coordinator;
pub mod govern;
pub mod harness;
pub mod memsim;
pub mod optimizer;
pub mod runtime;
pub mod stats;
pub mod stream;
pub mod testkit;
pub mod trace;
pub mod util;

pub use api::{
    Dataset, Emitter, InputSource, JobBuilder, JobConfig, JobOutput, KeyValue, MapReduce,
    Mapper, Pipeline, PlanHandle, PlanOutput, PlanReport, Reducer, Runtime,
};
pub use cache::{CacheActivity, CacheStats, MaterializationCache, Residency, TierDecision};
pub use govern::{
    Admission, AdmissionError, GovernReport, Governor, OverloadPolicy, Priority, Scoreboard,
    TenantId, TenantSnapshot, TenantSpec,
};
pub use optimizer::agent::OptimizerAgent;
pub use stats::{AdaptationReport, AdaptiveDecision, PrefixCost, StatsStore};
pub use stream::{
    AppendLog, KeyedStream, StandingQuery, StreamDataset, StreamHandle, StreamOutput,
    StreamSource, WindowResult, WindowSpec, Windowed, WindowedStream,
};
pub use trace::{MetricValue, MetricsRegistry, MetricsSnapshot, SpanKind, TraceSummary, Tracer};
