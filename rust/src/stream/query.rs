//! Standing queries: the lazy plan surface over an unbounded source,
//! and the per-chunk execution loop behind it.
//!
//! A [`StreamDataset`] records element-wise stages exactly like the
//! batch [`Dataset`](crate::api::plan::Dataset); keying and windowing it
//! builds a [`StandingQuery`]. Lowering happens **once** at build time —
//! the session agent's whole-plan pass fuses the element-wise chain into
//! the per-chunk extraction closure, so each arriving chunk pays one
//! fused pass plus pane folding, never a per-chunk re-plan.

use std::hash::Hash;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::api::config::{JobConfig, OptimizeMode};
use crate::api::keyed::{Aggregator, Count, Merge};
use crate::api::plan::{Chain, PlanReport, StageInfo, StageKind};
use crate::api::runtime::Runtime;
use crate::api::traits::HeapSized;
use crate::cache::CacheActivity;
use crate::coordinator::pipeline::{batch_for, StreamMetrics};
use crate::coordinator::planner;
use crate::govern::{Admission, GovernReport};
use crate::coordinator::splitter::split_indices;
use crate::stats::{AdaptationReport, FlowObservation};
use crate::stream::source::StreamSource;
use crate::stream::window::{
    merge_gate, StreamOutput, TsFn, WindowEngine, WindowResult, WindowSpec,
};

/// Below this chunk size the per-chunk extraction runs inline — the
/// pool handoff costs more than the fused pass saves.
const PARALLEL_CHUNK_MIN: usize = 1024;

/// A boxed fused extractor: barrier element in, stamped `(ts, key,
/// value)` pairs out.
type ExtractFn<'rt, B, K, V> = Box<dyn Fn(&B, &mut dyn FnMut(u64, K, V)) + Send + Sync + 'rt>;

/// A lazy element-wise plan over an unbounded [`StreamSource`] — the
/// streaming twin of [`Dataset`](crate::api::plan::Dataset). Recording
/// stages executes nothing; keying and windowing it produces the
/// [`StandingQuery`] that runs.
pub struct StreamDataset<'rt, T, B = T> {
    rt: &'rt Runtime,
    source: StreamSource<B>,
    chain: Chain<'rt, B, T>,
    stages: Vec<StageInfo>,
    config: JobConfig,
}

impl<'rt, T: 'rt> StreamDataset<'rt, T> {
    pub(crate) fn over(
        rt: &'rt Runtime,
        source: StreamSource<T>,
        config: JobConfig,
    ) -> StreamDataset<'rt, T> {
        let optimize = config.optimize;
        StreamDataset {
            rt,
            source,
            chain: Chain::direct(),
            stages: vec![StageInfo {
                kind: StageKind::Source,
                name: "stream".to_string(),
                optimize,
                token: None,
            }],
            config,
        }
    }
}

impl<'rt, T: 'rt, B: 'rt> StreamDataset<'rt, T, B> {
    /// Logical stages recorded so far.
    pub fn stages(&self) -> &[StageInfo] {
        &self.stages
    }

    /// Replace the configuration for subsequently recorded stages.
    pub fn with_config(mut self, config: JobConfig) -> Self {
        self.config = config;
        self.rt.resolve_govern(&mut self.config);
        self
    }

    pub fn optimize(mut self, mode: OptimizeMode) -> Self {
        self.config = self.config.with_optimize(mode);
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.config = self.config.with_threads(n);
        self
    }

    fn push_stage(&mut self, kind: StageKind, name: &str) {
        self.stages.push(StageInfo {
            kind,
            name: name.to_string(),
            optimize: self.config.optimize,
            token: None,
        });
    }

    /// Record a one-to-one element transform.
    pub fn map<U: 'rt>(
        self,
        f: impl Fn(&T) -> U + Send + Sync + 'rt,
    ) -> StreamDataset<'rt, U, B> {
        self.map_named("map", f)
    }

    fn map_named<U: 'rt>(
        mut self,
        name: &str,
        f: impl Fn(&T) -> U + Send + Sync + 'rt,
    ) -> StreamDataset<'rt, U, B> {
        self.push_stage(StageKind::Map, name);
        let chain = match self.chain {
            Chain::Direct { by_ref, .. } => Chain::Ops {
                op: Box::new(move |b: &B, sink: &mut dyn FnMut(&U)| {
                    let u = f(by_ref(b));
                    sink(&u);
                }),
            },
            Chain::Ops { op } => Chain::Ops {
                op: Box::new(move |b: &B, sink: &mut dyn FnMut(&U)| {
                    op(b, &mut |t: &T| {
                        let u = f(t);
                        sink(&u);
                    })
                }),
            },
        };
        StreamDataset {
            rt: self.rt,
            source: self.source,
            chain,
            stages: self.stages,
            config: self.config,
        }
    }

    /// Record an element predicate.
    pub fn filter(
        mut self,
        p: impl Fn(&T) -> bool + Send + Sync + 'rt,
    ) -> StreamDataset<'rt, T, B> {
        self.push_stage(StageKind::Filter, "filter");
        let chain = match self.chain {
            Chain::Direct { by_ref, .. } => Chain::Ops {
                op: Box::new(move |b: &B, sink: &mut dyn FnMut(&T)| {
                    let t = by_ref(b);
                    if p(t) {
                        sink(t);
                    }
                }),
            },
            Chain::Ops { op } => Chain::Ops {
                op: Box::new(move |b: &B, sink: &mut dyn FnMut(&T)| {
                    op(b, &mut |t: &T| {
                        if p(t) {
                            sink(t);
                        }
                    })
                }),
            },
        };
        StreamDataset {
            rt: self.rt,
            source: self.source,
            chain,
            stages: self.stages,
            config: self.config,
        }
    }

    /// Record a one-to-many element transform.
    pub fn flat_map<U: 'rt>(
        mut self,
        f: impl Fn(&T, &mut dyn FnMut(U)) + Send + Sync + 'rt,
    ) -> StreamDataset<'rt, U, B> {
        self.push_stage(StageKind::FlatMap, "flat_map");
        let chain = match self.chain {
            Chain::Direct { by_ref, .. } => Chain::Ops {
                op: Box::new(move |b: &B, sink: &mut dyn FnMut(&U)| {
                    f(by_ref(b), &mut |u: U| sink(&u))
                }),
            },
            Chain::Ops { op } => Chain::Ops {
                op: Box::new(move |b: &B, sink: &mut dyn FnMut(&U)| {
                    op(b, &mut |t: &T| f(t, &mut |u: U| sink(&u)))
                }),
            },
        };
        StreamDataset {
            rt: self.rt,
            source: self.source,
            chain,
            stages: self.stages,
            config: self.config,
        }
    }

    /// Pair every element with a key — the keyed streaming view.
    pub fn key_by<K: 'rt>(
        self,
        f: impl Fn(&T) -> K + Send + Sync + 'rt,
    ) -> KeyedStream<'rt, K, T, B>
    where
        T: Clone,
    {
        KeyedStream {
            inner: self.map_named("key_by", move |t| (f(t), t.clone())),
        }
    }
}

impl<'rt, K: 'rt, V: 'rt, B: 'rt> StreamDataset<'rt, (K, V), B> {
    /// Treat a stream of pairs as keyed without re-mapping.
    pub fn keyed(self) -> KeyedStream<'rt, K, V, B> {
        KeyedStream { inner: self }
    }
}

/// A keyed unbounded stream — pairs `(K, V)` awaiting a window
/// assignment. The streaming twin of
/// [`KeyedDataset`](crate::api::keyed::KeyedDataset); aggregation
/// requires a window, because an unbounded feed has no "end" to
/// aggregate at.
pub struct KeyedStream<'rt, K, V, B = (K, V)> {
    inner: StreamDataset<'rt, (K, V), B>,
}

impl<'rt, K: 'rt, V: 'rt, B: 'rt> KeyedStream<'rt, K, V, B> {
    /// Assign pairs to tumbling (non-overlapping) event-time windows of
    /// `size` ticks, timestamps extracted by `ts`.
    pub fn window_tumbling(
        self,
        size: u64,
        ts: impl Fn(&V) -> u64 + Send + Sync + 'rt,
    ) -> WindowedStream<'rt, K, V, B> {
        WindowedStream {
            inner: self.inner,
            spec: WindowSpec::tumbling(size),
            ts: Box::new(ts),
        }
    }

    /// Assign pairs to sliding windows of `size` ticks advancing every
    /// `slide` ticks (`size % slide == 0`).
    pub fn window_sliding(
        self,
        size: u64,
        slide: u64,
        ts: impl Fn(&V) -> u64 + Send + Sync + 'rt,
    ) -> WindowedStream<'rt, K, V, B> {
        WindowedStream {
            inner: self.inner,
            spec: WindowSpec::sliding(size, slide),
            ts: Box::new(ts),
        }
    }
}

/// A keyed stream with a window assignment — one aggregation call away
/// from a running [`StandingQuery`]. The batch twin is
/// [`Windowed`](crate::stream::Windowed).
pub struct WindowedStream<'rt, K, V, B = (K, V)> {
    inner: StreamDataset<'rt, (K, V), B>,
    spec: WindowSpec,
    ts: TsFn<'rt, V>,
}

impl<'rt, K: 'rt, V: 'rt, B: 'rt> WindowedStream<'rt, K, V, B> {
    /// Turn the recorded plan into a standing query aggregating per
    /// `(window, key)` with a declared [`Aggregator`]. The plan lowers
    /// once, here; the merge-vs-recompute gate mirrors the batch combine
    /// gate (see [`crate::stream`]).
    pub fn aggregate_by_key<H, O, A>(self, agg: A) -> StandingQuery<'rt, B, K, V, H, O, A>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + HeapSized,
        V: Clone + Send + HeapSized,
        H: Clone,
        A: Aggregator<V, H, O> + 'rt,
    {
        let WindowedStream { inner, spec, ts } = self;
        let StreamDataset {
            rt,
            source,
            chain,
            mut stages,
            config,
        } = inner;
        let agg = Arc::new(agg);
        stages.push(StageInfo {
            kind: StageKind::KeyedAggregate,
            name: agg.name().to_string(),
            optimize: config.optimize,
            token: None,
        });
        // The single whole-plan pass: the agent sees the plan shape at
        // build time, not once per chunk. Adaptive configs consult the
        // session feedback store here — once per query, never per chunk.
        let mut plan = if config.adaptive_enabled() {
            let ctx = planner::AdaptiveCtx {
                store: rt.stats(),
                threads: config.threads,
            };
            planner::lower_adaptive(&stages, rt.agent(), rt.cache(), Some(&ctx))
        } else {
            planner::lower(&stages, rt.agent(), rt.cache())
        };
        let adaptation = plan.adaptation.take();
        // The aggregate stage's prefix fingerprint, under which each
        // `step()` feeds the engine's window-pane counters back to the
        // store (adaptive lowerings always compute fingerprints).
        let stats_fp = if config.adaptive_enabled() {
            plan.prefix_fps.last().copied()
        } else {
            None
        };
        let (merge, fallback) = merge_gate::<V, H, O, A>(&config, rt.agent(), agg.name());
        let engine =
            WindowEngine::new(spec, Arc::clone(&agg), merge, fallback, Arc::clone(&config.heap));
        let extract: ExtractFn<'rt, B, K, V> = match chain {
            Chain::Direct { by_ref, .. } => {
                Box::new(move |b: &B, sink: &mut dyn FnMut(u64, K, V)| {
                    let pair = by_ref(b);
                    sink(ts(&pair.1), pair.0.clone(), pair.1.clone());
                })
            }
            Chain::Ops { op } => Box::new(move |b: &B, sink: &mut dyn FnMut(u64, K, V)| {
                op(b, &mut |pair: &(K, V)| {
                    sink(ts(&pair.1), pair.0.clone(), pair.1.clone());
                });
            }),
        };
        StandingQuery {
            rt,
            source,
            extract,
            engine,
            config,
            fused_ops: plan.fused_ops,
            streamed_handoffs: plan.streamed_handoffs,
            adaptation,
            stats_fp,
            last_blocked: 0,
            last_shed: 0,
        }
    }

    /// Count pairs per `(window, key)` (mergeable: pane counts add).
    pub fn count_by_key(self) -> StandingQuery<'rt, B, K, V, i64, i64, Count>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + HeapSized,
        V: Clone + Send + Sync + HeapSized,
    {
        self.aggregate_by_key(Count)
    }

    /// Reduce values per `(window, key)` with a binary merge function
    /// declared associative + commutative (mergeable holders).
    pub fn reduce_by_key<F>(
        self,
        merge: F,
    ) -> StandingQuery<'rt, B, K, V, Option<V>, V, Merge<F>>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + HeapSized,
        V: Clone + Send + Sync + HeapSized,
        F: Fn(V, V) -> V + Send + Sync + 'rt,
    {
        self.aggregate_by_key(Merge::new(merge))
    }
}

/// A live windowed aggregation over an unbounded feed: pull a chunk,
/// run the fused extraction (in parallel on the session pool for large
/// chunks), fold into panes, fire every window the watermark closed.
///
/// Drive it with [`StandingQuery::step`] for chunk-at-a-time results,
/// or [`StandingQuery::run_to_close`] to drain the feed. Counters
/// accumulate in [`StandingQuery::metrics`] and land in the final
/// [`StreamOutput::report`].
pub struct StandingQuery<'rt, B, K, V, H, O, A> {
    rt: &'rt Runtime,
    source: StreamSource<B>,
    extract: ExtractFn<'rt, B, K, V>,
    engine: WindowEngine<K, V, H, O, A>,
    config: JobConfig,
    fused_ops: usize,
    streamed_handoffs: usize,
    /// What build-time adaptive lowering decided (rides the final
    /// report as [`PlanReport::adaptation`]). `None` on static configs.
    adaptation: Option<AdaptationReport>,
    /// The aggregate stage's prefix fingerprint, under which each ingest
    /// records window-pane statistics. `None` on static configs.
    stats_fp: Option<u64>,
    /// Source-side backpressure counters already folded into the
    /// tenant scoreboard (the sync is delta-based, once per ingest).
    last_blocked: u64,
    last_shed: u64,
}

impl<'rt, B, K, V, H, O, A> StandingQuery<'rt, B, K, V, H, O, A>
where
    B: Send + Sync,
    K: Hash + Eq + Clone + Send + HeapSized,
    V: Clone + Send + HeapSized,
    H: Clone,
    A: Aggregator<V, H, O>,
{
    /// Block for the next chunk, ingest it, and return the windows it
    /// closed (often empty — windows fire only when the watermark passes
    /// them). `None` once the feed is closed and drained; call
    /// [`StandingQuery::finish`] then for the force-fired tail.
    pub fn step(&mut self) -> Option<Vec<WindowResult<K, O>>> {
        let chunk = self.source.pull()?;
        Some(self.ingest(&chunk))
    }

    /// The accumulated streaming counters so far.
    pub fn metrics(&self) -> &StreamMetrics {
        self.engine.metrics()
    }

    /// Force-fire every window still holding data (end-of-stream) and
    /// return the output. Windows already returned by
    /// [`StandingQuery::step`] are **not** repeated — the output holds
    /// only the tail.
    pub fn finish(mut self) -> StreamOutput<K, O> {
        let tail = self.engine.finish();
        self.into_output(tail)
    }

    /// Drain the feed to close, then force-fire: every window of the
    /// whole stream, in order. Blocks until the producer closes the
    /// handle.
    pub fn run_to_close(mut self) -> StreamOutput<K, O> {
        let mut windows = Vec::new();
        while let Some(chunk) = self.source.pull() {
            windows.extend(self.ingest(&chunk));
        }
        windows.extend(self.engine.finish());
        self.into_output(windows)
    }

    fn ingest(&mut self, chunk: &[B]) -> Vec<WindowResult<K, O>> {
        // The streaming backpressure gate: a governed query under
        // pressure *delays* the ingest (it never drops the chunk —
        // results stay digest-identical to an ungoverned run).
        if let Some(tenant) = &self.config.govern {
            self.rt.governor().gate_ingest(tenant, &self.config.heap);
        }
        self.sync_backpressure();
        let stamped = self.extract_chunk(chunk);
        let fired = self.engine.ingest_chunk(stamped);
        self.record_pane_stats();
        fired
    }

    /// Feed the engine's cumulative window-pane counters back to the
    /// session [`StatsStore`](crate::stats::StatsStore) under the
    /// aggregate stage's prefix fingerprint.
    ///
    /// Pane observations are reporting-grade: keys are unknown at pane
    /// granularity (recorded as zero), so no lowering hint ever derives
    /// from them — they surface in [`StatsStore`](crate::stats::StatsStore)
    /// record counts and diagnostics only. Stream sources fingerprint as
    /// `"stream"` (batch plans use `"source"`), so stream observations
    /// can never alias a batch prefix.
    fn record_pane_stats(&self) {
        let Some(fp) = self.stats_fp else { return };
        let m = self.engine.metrics();
        self.rt.stats().record_flow(
            fp,
            FlowObservation {
                emits: m.elements_ingested,
                keys: 0,
                results: m.windows_fired,
                shuffled_bytes: 0,
                combine_flow: m.merge_mode,
                declared: true,
                mergeable: m.merge_mode,
                total_secs: 0.0,
                skew: None,
            },
        );
    }

    /// Fold the source-side backpressure counters into the tenant
    /// scoreboard: the delta since the previous sync, so mid-flight
    /// [`Runtime::scoreboard`](crate::api::Runtime::scoreboard) reads
    /// stay current while the query runs.
    fn sync_backpressure(&mut self) {
        let blocked = self.source.pushes_blocked();
        let shed = self.source.pushes_shed();
        if let Some(tenant) = &self.config.govern {
            let c = tenant.counters();
            c.stream_pushes_blocked
                .fetch_add(blocked.saturating_sub(self.last_blocked), Ordering::Relaxed);
            c.stream_pushes_shed
                .fetch_add(shed.saturating_sub(self.last_shed), Ordering::Relaxed);
        }
        self.last_blocked = blocked;
        self.last_shed = shed;
    }

    /// Run the fused chain + timestamp stamping over one chunk. Large
    /// chunks split into contiguous ranges across the session pool;
    /// range-order concatenation preserves arrival order.
    fn extract_chunk(&self, chunk: &[B]) -> Vec<(u64, K, V)> {
        let threads = self.config.threads.max(1);
        if threads <= 1 || chunk.len() < PARALLEL_CHUNK_MIN {
            let mut out = Vec::with_capacity(chunk.len());
            for element in chunk {
                (self.extract)(element, &mut |ts, key, value| out.push((ts, key, value)));
            }
            return out;
        }
        let ranges = split_indices(chunk.len(), threads);
        let slots: Vec<Mutex<Vec<(u64, K, V)>>> =
            (0..ranges.len()).map(|_| Mutex::new(Vec::new())).collect();
        let extract = &self.extract;
        let tasks: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(slot_idx, range)| {
                let slots = &slots;
                move |_worker: usize| {
                    let mut local = Vec::with_capacity(range.len());
                    for element in &chunk[range] {
                        extract(element, &mut |ts, key, value| local.push((ts, key, value)));
                    }
                    *slots[slot_idx].lock().unwrap() = local;
                }
            })
            .collect();
        batch_for(self.rt.pool(), &self.config).run(threads, tasks);
        let mut out = Vec::with_capacity(chunk.len());
        for slot in slots {
            out.extend(slot.into_inner().unwrap());
        }
        out
    }

    fn into_output(mut self, windows: Vec<WindowResult<K, O>>) -> StreamOutput<K, O> {
        self.sync_backpressure();
        let mut metrics = self.engine.metrics().clone();
        metrics.pushes_blocked = self.source.pushes_blocked();
        metrics.pushes_shed = self.source.pushes_shed();
        // Streaming admission acts per-ingest at the backpressure gate
        // (outcomes land on the scoreboard), so the report's admission is
        // nominally clean — see [`GovernReport`].
        let govern = self.config.govern.as_ref().map(|tenant| GovernReport {
            tenant: tenant.id(),
            name: tenant.spec().name.clone(),
            priority: tenant.spec().priority,
            quota: tenant.quota(),
            admission: Admission::Clean,
        });
        StreamOutput {
            windows,
            report: PlanReport {
                stage_metrics: Vec::new(),
                fused_ops: self.fused_ops,
                streamed_handoffs: self.streamed_handoffs,
                materialized_pairs: 0,
                cache: CacheActivity::default(),
                stream: Some(metrics),
                govern,
                adaptation: self.adaptation.take(),
                trace: None,
            },
        }
    }
}
