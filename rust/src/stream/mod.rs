//! Continuous streaming dataflow: unbounded sources, standing queries,
//! and event-time windowed keyed aggregation.
//!
//! The batch surface ([`crate::api::plan::Dataset`]) drains its source
//! once at `collect()`. This module keeps the same logical plan **live**
//! over a feed that never ends: [`crate::api::Runtime::stream`] opens a
//! [`StreamDataset`] over a [`StreamSource`], element-wise stages record
//! exactly as on the batch surface, and a windowed keyed aggregation
//! turns the plan into a [`StandingQuery`] that re-fires per arriving
//! chunk instead of returning once.
//!
//! The streaming optimization is the paper's combining flow extended
//! across time. Each event-time **pane** (one window slide's worth of
//! elements) folds values into per-key holders at ingest — the same
//! `initialize`/`combine` holder triple the declared
//! [`Aggregator`](crate::api::keyed::Aggregator) algebra uses for batch
//! reduces. When a window fires, its panes' holders are **merged**
//! ([`Aggregator::merge_holders`](crate::api::keyed::Aggregator::merge_holders))
//! rather than its raw values re-folded, so sliding windows that share
//! panes never recompute a value twice. The merge path is gated exactly
//! like the batch combine path: the session agent must accept the
//! aggregator's declared associativity + commutativity, the holder must
//! declare [`MERGEABLE`](crate::api::keyed::Aggregator::MERGEABLE), and
//! the optimizer must be on — otherwise panes buffer raw pairs and every
//! window close re-folds them from scratch (correct, measured, slower;
//! see [`StreamMetrics`](crate::coordinator::pipeline::StreamMetrics)).
//!
//! Batch plans get the same window algebra through
//! [`KeyedDataset::window_tumbling`](crate::api::keyed::KeyedDataset::window_tumbling)
//! (a [`Windowed`] view that collects once and fires all windows), and
//! append-only batch sources get **incremental cache maintenance**: a
//! [`Dataset::cache`](crate::api::plan::Dataset::cache) cut over an
//! [`AppendLog`] recomputes only the appended tail on re-collect and
//! merges it into the cached entry (see [`crate::cache`]).

pub mod query;
pub mod source;
pub mod window;

pub use query::{KeyedStream, StandingQuery, StreamDataset, WindowedStream};
pub use source::{AppendLog, StreamHandle, StreamSource};
pub use window::{StreamOutput, WindowResult, WindowSpec, Windowed};
