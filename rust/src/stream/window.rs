//! Event-time windows over keyed pairs: pane bookkeeping, the
//! merge-vs-recompute window engine, and the batch [`Windowed`] view.
//!
//! The model is pane-based. A **pane** is one window slide's worth of
//! event time (`slide` ticks); every element lands in exactly one pane
//! (`pane = ts / slide`). A **window** `w` spans the `size / slide`
//! consecutive panes `[w, w + size/slide)` — the event-time range
//! `[w * slide, w * slide + size)` — and fires once the watermark (max
//! timestamp seen) passes its end. Tumbling windows are the
//! `slide == size` special case: one pane per window.
//!
//! On the merge path each pane folds values into per-key holders at
//! ingest and a firing window merges its panes' holders; on the fallback
//! path panes buffer raw pairs and a firing window re-folds them. A pane
//! retires — its simulated-heap bytes are freed — as soon as the last
//! window covering it has fired.

use std::collections::hash_map::Entry as MapEntry;
use std::collections::BTreeMap;
use std::hash::Hash;
use std::marker::PhantomData;
use std::sync::Arc;

use crate::api::config::{JobConfig, OptimizeMode};
use crate::api::keyed::{Aggregator, Count, Merge};
use crate::api::plan::Dataset;
use crate::api::traits::{HeapSized, KeyValue};
use crate::coordinator::pipeline::StreamMetrics;
use crate::memsim::{CohortId, SimHeap, ThreadAlloc};
use crate::optimizer::agent::OptimizerAgent;
use crate::trace::SpanKind;
use crate::util::hash::FxHashMap;

/// A boxed event-timestamp extractor (`&V -> u64` ticks).
pub(crate) type TsFn<'rt, V> = Box<dyn Fn(&V) -> u64 + Send + Sync + 'rt>;

/// Simulated bytes for one per-key holder slot on the merge path
/// (holder object header + map slot).
const HOLDER_SLOT_BYTES: u64 = 32;

/// Simulated bytes for one buffered `(key, value)` slot on the fallback
/// path, on top of the key's and value's own heap bytes.
const PAIR_SLOT_BYTES: u64 = 16;

/// An event-time window shape: `size` ticks wide, advancing by `slide`
/// ticks. `size` must be a positive multiple of `slide`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSpec {
    /// Window width in event-time ticks.
    pub size: u64,
    /// Window advance in event-time ticks (pane width).
    pub slide: u64,
}

impl WindowSpec {
    /// Non-overlapping windows: `slide == size`.
    pub fn tumbling(size: u64) -> WindowSpec {
        WindowSpec::sliding(size, size)
    }

    /// Overlapping windows of `size` ticks every `slide` ticks.
    ///
    /// # Panics
    /// If `size` or `slide` is zero, or `size % slide != 0` (windows
    /// must cover whole panes).
    pub fn sliding(size: u64, slide: u64) -> WindowSpec {
        assert!(size > 0 && slide > 0, "window size and slide must be positive");
        assert!(
            size % slide == 0,
            "window size ({size}) must be a multiple of slide ({slide})"
        );
        WindowSpec { size, slide }
    }

    pub(crate) fn panes_per_window(&self) -> u64 {
        self.size / self.slide
    }
}

/// One fired window: its ordinal, event-time bounds, and aggregated
/// per-key results (unordered; digest or sort for deterministic output).
#[derive(Clone, Debug, PartialEq)]
pub struct WindowResult<K, O> {
    /// Window ordinal — the id of the first pane it covers.
    pub window: u64,
    /// Inclusive event-time start tick.
    pub start: u64,
    /// Exclusive event-time end tick.
    pub end: u64,
    /// Aggregated output per key seen in the window.
    pub pairs: Vec<KeyValue<K, O>>,
}

/// What a finished windowed aggregation returns: every fired window in
/// firing order, plus the plan report carrying
/// [`StreamMetrics`](crate::coordinator::pipeline::StreamMetrics).
#[derive(Clone, Debug)]
pub struct StreamOutput<K, O> {
    /// Fired windows, in window order.
    pub windows: Vec<WindowResult<K, O>>,
    /// Plan-level report; [`PlanReport::stream`](crate::api::PlanReport)
    /// is always populated here.
    pub report: crate::api::plan::PlanReport,
}

impl<K, O> StreamOutput<K, O> {
    /// The streaming counters (always present on a stream output).
    pub fn metrics(&self) -> &StreamMetrics {
        self.report
            .stream
            .as_ref()
            .expect("stream outputs always carry stream metrics")
    }

    pub fn into_windows(self) -> Vec<WindowResult<K, O>> {
        self.windows
    }
}

/// Decide whether a windowed aggregation may merge pane holders, exactly
/// mirroring the batch combine gate: optimizer on, declared semantics
/// accepted by the session agent, and a holder that declares
/// [`Aggregator::MERGEABLE`]. Returns `(merge, fallback_reason)`.
pub(crate) fn merge_gate<V, H, O, A>(
    cfg: &JobConfig,
    agent: &OptimizerAgent,
    name: &str,
) -> (bool, Option<String>)
where
    A: Aggregator<V, H, O>,
{
    if matches!(cfg.optimize, OptimizeMode::Off) {
        return (false, Some("optimizer off".to_string()));
    }
    if !agent.process_declared(name, A::ASSOCIATIVE, A::COMMUTATIVE) {
        let why = if A::ASSOCIATIVE {
            "declared non-commutative"
        } else {
            "declared non-associative"
        };
        return (false, Some(why.to_string()));
    }
    if !A::MERGEABLE {
        return (false, Some("holder not mergeable".to_string()));
    }
    (true, None)
}

/// One pane's state: per-key holders on the merge path, buffered raw
/// pairs on the fallback path, plus its simulated-heap charge.
struct Pane<K, V, H> {
    holders: FxHashMap<K, H>,
    buffer: Vec<(K, V)>,
    bytes: u64,
}

impl<K, V, H> Default for Pane<K, V, H> {
    fn default() -> Self {
        Pane {
            holders: FxHashMap::default(),
            buffer: Vec::new(),
            bytes: 0,
        }
    }
}

/// The window state machine shared by streaming standing queries and
/// batch [`Windowed`] collects: ingest stamped pairs into panes, fire
/// windows as the watermark passes them, retire panes whose last window
/// fired.
pub(crate) struct WindowEngine<K, V, H, O, A> {
    spec: WindowSpec,
    agg: Arc<A>,
    merge_mode: bool,
    panes: BTreeMap<u64, Pane<K, V, H>>,
    /// The next window to fire; panes below it have retired, so elements
    /// landing below it are late.
    next_window: u64,
    /// Watermark: the maximum event timestamp observed.
    max_ts: Option<u64>,
    last_fired_end: u64,
    metrics: StreamMetrics,
    heap: Arc<SimHeap>,
    alloc: ThreadAlloc,
    pane_cohort: CohortId,
    _out: PhantomData<fn() -> O>,
}

impl<K, V, H, O, A> Drop for WindowEngine<K, V, H, O, A> {
    fn drop(&mut self) {
        self.alloc.flush();
        self.heap.release_cohort(self.pane_cohort);
    }
}

impl<K, V, H, O, A> WindowEngine<K, V, H, O, A>
where
    K: Hash + Eq + Clone + HeapSized,
    V: Clone + HeapSized,
    H: Clone,
    A: Aggregator<V, H, O>,
{
    pub(crate) fn new(
        spec: WindowSpec,
        agg: Arc<A>,
        merge_mode: bool,
        fallback_reason: Option<String>,
        heap: Arc<SimHeap>,
    ) -> Self {
        let pane_cohort = heap.scoped_cohort("stream.pane");
        let alloc = heap.thread_alloc();
        WindowEngine {
            spec,
            agg,
            merge_mode,
            panes: BTreeMap::new(),
            next_window: 0,
            max_ts: None,
            last_fired_end: 0,
            metrics: StreamMetrics {
                merge_mode,
                fallback_reason,
                ..StreamMetrics::default()
            },
            heap,
            alloc,
            pane_cohort,
            _out: PhantomData,
        }
    }

    pub(crate) fn metrics(&self) -> &StreamMetrics {
        &self.metrics
    }

    /// Ingest one stamped chunk, then fire every window the advanced
    /// watermark closes. Returns the fired windows in window order.
    pub(crate) fn ingest_chunk(&mut self, stamped: Vec<(u64, K, V)>) -> Vec<WindowResult<K, O>> {
        self.metrics.chunks_ingested += 1;
        for (ts, key, value) in stamped {
            self.ingest_one(ts, key, value);
        }
        let mut fired = Vec::new();
        self.fire_ready(false, &mut fired);
        fired
    }

    /// Force-fire every window still holding data (end-of-stream).
    pub(crate) fn finish(&mut self) -> Vec<WindowResult<K, O>> {
        let mut fired = Vec::new();
        self.fire_ready(true, &mut fired);
        fired
    }

    fn ingest_one(&mut self, ts: u64, key: K, value: V) {
        self.metrics.elements_ingested += 1;
        let pane_id = ts / self.spec.slide;
        if pane_id < self.next_window {
            // Every window covering this pane has already fired.
            self.metrics.late_elements += 1;
            return;
        }
        self.max_ts = Some(self.max_ts.map_or(ts, |m| m.max(ts)));
        let pane = self.panes.entry(pane_id).or_default();
        let charged = if self.merge_mode {
            match pane.holders.entry(key) {
                MapEntry::Occupied(mut slot) => {
                    self.agg.combine(slot.get_mut(), value);
                    0
                }
                MapEntry::Vacant(slot) => {
                    let bytes = slot.key().heap_bytes() + HOLDER_SLOT_BYTES;
                    let mut holder = self.agg.init();
                    self.agg.combine(&mut holder, value);
                    slot.insert(holder);
                    bytes
                }
            }
        } else {
            let bytes = key.heap_bytes() + value.heap_bytes() + PAIR_SLOT_BYTES;
            pane.buffer.push((key, value));
            bytes
        };
        if charged > 0 {
            pane.bytes += charged;
            self.alloc.alloc(self.pane_cohort, charged);
        }
    }

    fn fire_ready(&mut self, force: bool, out: &mut Vec<WindowResult<K, O>>) {
        let ppw = self.spec.panes_per_window();
        loop {
            let Some((&first_pane, _)) = self.panes.first_key_value() else {
                break;
            };
            // Skip windows covering no remaining pane — they would be
            // empty. The earliest non-empty window is the last one whose
            // span still reaches the first live pane.
            let earliest = first_pane.saturating_sub(ppw - 1);
            if earliest > self.next_window {
                self.next_window = earliest;
            }
            let window = self.next_window;
            let end = window * self.spec.slide + self.spec.size;
            let ready = force || self.max_ts.is_some_and(|ts| ts >= end);
            if !ready {
                break;
            }
            out.push(self.fire_window(window, ppw));
            self.next_window = window + 1;
            self.retire_through(window);
        }
    }

    fn fire_window(&mut self, window: u64, ppw: u64) -> WindowResult<K, O> {
        let fire_start = self.heap.obs().map(|o| o.tracer.now_us());
        let mut acc: FxHashMap<K, H> = FxHashMap::default();
        let span = window..window.saturating_add(ppw);
        let mut panes_covered = 0u64;
        if self.merge_mode {
            let mut merged = 0u64;
            for (&pane_id, pane) in self.panes.range(span) {
                panes_covered += 1;
                if let Some(o) = self.heap.obs() {
                    o.tracer
                        .instant(SpanKind::PaneMerge, pane_id * self.spec.slide, 0);
                }
                for (key, holder) in &pane.holders {
                    merged += 1;
                    match acc.entry(key.clone()) {
                        MapEntry::Occupied(mut slot) => {
                            self.agg.merge_holders(slot.get_mut(), holder.clone());
                        }
                        MapEntry::Vacant(slot) => {
                            slot.insert(holder.clone());
                        }
                    }
                }
            }
            self.metrics.holders_merged += merged;
        } else {
            let mut refolded = 0u64;
            for (_, pane) in self.panes.range(span) {
                panes_covered += 1;
                for (key, value) in &pane.buffer {
                    refolded += 1;
                    match acc.entry(key.clone()) {
                        MapEntry::Occupied(mut slot) => {
                            self.agg.combine(slot.get_mut(), value.clone());
                        }
                        MapEntry::Vacant(slot) => {
                            let mut holder = self.agg.init();
                            self.agg.combine(&mut holder, value.clone());
                            slot.insert(holder);
                        }
                    }
                }
            }
            self.metrics.elements_recomputed += refolded;
            self.metrics.holders_recomputed += acc.len() as u64;
        }
        let pairs: Vec<KeyValue<K, O>> = acc
            .into_iter()
            .map(|(key, holder)| KeyValue::new(key, self.agg.finish(holder)))
            .collect();
        self.metrics.windows_fired += 1;
        let start = window * self.spec.slide;
        let end = start + self.spec.size;
        self.last_fired_end = end;
        self.metrics.watermark_lag = self
            .max_ts
            .unwrap_or(self.last_fired_end)
            .saturating_sub(self.last_fired_end);
        if let Some(o) = self.heap.obs() {
            o.tracer
                .record_since(SpanKind::PaneFire, fire_start.unwrap_or(0), end, panes_covered);
            o.metrics
                .gauge("stream.watermark_lag_ms")
                .set(self.metrics.watermark_lag);
        }
        WindowResult {
            window,
            start,
            end,
            pairs,
        }
    }

    /// Retire every pane the last fired window was the final consumer
    /// of, freeing its simulated-heap charge.
    fn retire_through(&mut self, through: u64) {
        while self
            .panes
            .first_key_value()
            .is_some_and(|(&id, _)| id <= through)
        {
            if let Some((_, pane)) = self.panes.pop_first() {
                self.metrics.panes_fired += 1;
                if pane.bytes > 0 {
                    self.alloc.free(self.pane_cohort, pane.bytes);
                }
            }
        }
    }
}

/// A windowed view over a **batch** keyed plan (from
/// [`KeyedDataset::window_tumbling`](crate::api::keyed::KeyedDataset::window_tumbling)
/// /
/// [`KeyedDataset::window_sliding`](crate::api::keyed::KeyedDataset::window_sliding)):
/// collecting it runs the upstream plan once, routes every pair through
/// the same pane engine a standing query uses, and fires all windows.
/// The streaming twin is [`WindowedStream`](crate::stream::WindowedStream).
pub struct Windowed<'rt, K, V, B = (K, V)> {
    inner: Dataset<'rt, (K, V), B>,
    spec: WindowSpec,
    ts: TsFn<'rt, V>,
}

impl<'rt, K: 'rt, V: 'rt, B: 'rt> Windowed<'rt, K, V, B> {
    pub(crate) fn over(
        inner: Dataset<'rt, (K, V), B>,
        spec: WindowSpec,
        ts: impl Fn(&V) -> u64 + Send + Sync + 'rt,
    ) -> Self {
        Windowed {
            inner,
            spec,
            ts: Box::new(ts),
        }
    }

    /// The window shape this view applies.
    pub fn spec(&self) -> WindowSpec {
        self.spec
    }

    /// Execute the upstream plan and aggregate per `(window, key)` with
    /// a declared [`Aggregator`]. The merge-vs-recompute decision follows
    /// the same gate as the batch combine path; see
    /// [`crate::stream`](crate::stream) for the semantics table.
    pub fn aggregate_by_key<H, O, A>(self, agg: A) -> StreamOutput<K, O>
    where
        K: Hash + Eq + Clone + HeapSized,
        V: Clone + HeapSized,
        H: Clone,
        A: Aggregator<V, H, O>,
    {
        let Windowed { inner, spec, ts } = self;
        let rt = inner.rt;
        let cfg = inner.config.clone();
        let agg = Arc::new(agg);
        let (merge, fallback) = merge_gate::<V, H, O, A>(&cfg, rt.agent(), agg.name());
        let mut engine =
            WindowEngine::new(spec, Arc::clone(&agg), merge, fallback, Arc::clone(&cfg.heap));
        let collected = inner.collect();
        let mut report = collected.report;
        let stamped: Vec<(u64, K, V)> = collected
            .items
            .into_iter()
            .map(|(key, value)| (ts(&value), key, value))
            .collect();
        let mut windows = engine.ingest_chunk(stamped);
        windows.extend(engine.finish());
        report.stream = Some(engine.metrics().clone());
        StreamOutput { windows, report }
    }

    /// Count pairs per `(window, key)` (mergeable: pane counts add).
    pub fn count_by_key(self) -> StreamOutput<K, i64>
    where
        K: Hash + Eq + Clone + HeapSized,
        V: Clone + Send + Sync + HeapSized,
    {
        self.aggregate_by_key(Count)
    }

    /// Reduce values per `(window, key)` with a binary merge function
    /// declared associative + commutative (mergeable holders).
    pub fn reduce_by_key<F>(self, merge: F) -> StreamOutput<K, V>
    where
        K: Hash + Eq + Clone + HeapSized,
        V: Clone + Send + Sync + HeapSized,
        F: Fn(V, V) -> V + Send + Sync,
    {
        self.aggregate_by_key(Merge::new(merge))
    }
}
