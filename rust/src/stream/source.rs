//! Stream inputs: the push/pull chunk queue behind [`StreamSource`] —
//! unbounded, or bounded with producer backpressure
//! ([`StreamSource::bounded`]) — and the append-only [`AppendLog`] whose
//! cached prefixes are maintained incrementally.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::api::source::{Feed, InputSource};
use crate::util::hash::fxhash;

struct QueueState<T> {
    chunks: VecDeque<Vec<T>>,
    closed: bool,
}

struct SharedQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
    /// Backpressure bound, in **chunks**: while the queue holds this many,
    /// `push` blocks and `try_push` sheds. `None` = unbounded.
    capacity: Option<usize>,
    /// Signalled when a pull frees a slot (or the feed closes).
    space: Condvar,
    /// Pushes that blocked waiting for space (once per blocking push).
    blocked: AtomicU64,
    /// `try_push` chunks handed back because the queue was full.
    shed: AtomicU64,
}

impl<T> SharedQueue<T> {
    fn new(capacity: Option<usize>, chunks: VecDeque<Vec<T>>, closed: bool) -> Arc<SharedQueue<T>> {
        Arc::new(SharedQueue {
            state: Mutex::new(QueueState { chunks, closed }),
            ready: Condvar::new(),
            capacity,
            space: Condvar::new(),
            blocked: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        })
    }

    fn full(&self, state: &QueueState<T>) -> bool {
        match self.capacity {
            Some(cap) => state.chunks.len() >= cap,
            None => false,
        }
    }
}

/// Blocking dequeue: the next non-empty chunk, or `None` once the queue
/// is closed **and** drained. Empty chunks are skipped here, mirroring
/// the [`ChunkedSource`](crate::api::ChunkedSource) feed contract — an
/// empty push is a heartbeat, not end-of-stream.
fn pull_chunk<T>(queue: &SharedQueue<T>) -> Option<Vec<T>> {
    let mut state = queue.state.lock().unwrap();
    loop {
        match state.chunks.pop_front() {
            Some(chunk) if chunk.is_empty() => {
                queue.space.notify_all();
                continue;
            }
            Some(chunk) => {
                drop(state);
                queue.space.notify_all();
                return Some(chunk);
            }
            None if state.closed => return None,
            None => state = queue.ready.wait(state).unwrap(),
        }
    }
}

/// The consuming end of an unbounded chunk feed — what
/// [`Runtime::stream`](crate::api::Runtime::stream) opens a standing
/// plan over.
///
/// Producers hold the paired [`StreamHandle`] and `push` chunks from any
/// thread; the source blocks on pull until a chunk arrives or the handle
/// closes. `StreamSource` also implements [`InputSource`], so it can
/// feed a plain batch `collect()` — but a batch collect *blocks until
/// the handle closes* (it drains the feed to completion). For
/// chunk-at-a-time evaluation use a standing query instead.
pub struct StreamSource<T> {
    queue: Arc<SharedQueue<T>>,
}

/// The producing end of a [`StreamSource`]: `push` chunks, then `close`.
/// Cloneable — any number of producer threads may share one feed.
pub struct StreamHandle<T> {
    queue: Arc<SharedQueue<T>>,
}

impl<T> Clone for StreamHandle<T> {
    fn clone(&self) -> Self {
        StreamHandle {
            queue: Arc::clone(&self.queue),
        }
    }
}

impl<T> StreamSource<T> {
    /// An open feed: the source blocks until the handle pushes or closes.
    pub fn unbounded() -> (StreamSource<T>, StreamHandle<T>) {
        let queue = SharedQueue::new(None, VecDeque::new(), false);
        let source = StreamSource {
            queue: Arc::clone(&queue),
        };
        (source, StreamHandle { queue })
    }

    /// An open feed whose queue holds at most `capacity` chunks (clamped
    /// to ≥ 1) — the backpressure twin of [`StreamSource::unbounded`],
    /// closing the gap where a fast producer could outrun the pane engine
    /// unboundedly. Once full, [`StreamHandle::push`] blocks the producer
    /// until the consumer drains a chunk, and [`StreamHandle::try_push`]
    /// hands the chunk back instead. Blocked and shed pushes are counted
    /// ([`StreamSource::pushes_blocked`] / [`StreamSource::pushes_shed`])
    /// and surface in a standing query's
    /// [`StreamMetrics`](crate::coordinator::pipeline::StreamMetrics).
    pub fn bounded(capacity: usize) -> (StreamSource<T>, StreamHandle<T>) {
        let queue = SharedQueue::new(Some(capacity.max(1)), VecDeque::new(), false);
        let source = StreamSource {
            queue: Arc::clone(&queue),
        };
        (source, StreamHandle { queue })
    }

    /// A pre-loaded, already-closed feed — replays `chunks` in order and
    /// then reports end-of-stream. The deterministic-test twin of
    /// [`StreamSource::unbounded`].
    pub fn replay(chunks: Vec<Vec<T>>) -> StreamSource<T> {
        StreamSource {
            queue: SharedQueue::new(None, chunks.into(), true),
        }
    }

    /// Blocking pull of the next non-empty chunk (`None` = closed and
    /// drained).
    pub(crate) fn pull(&self) -> Option<Vec<T>> {
        pull_chunk(&self.queue)
    }

    /// Pushes that have blocked waiting for queue space so far (always 0
    /// on unbounded feeds).
    pub fn pushes_blocked(&self) -> u64 {
        self.queue.blocked.load(Ordering::Relaxed)
    }

    /// `try_push` chunks handed back at a full queue so far (always 0 on
    /// unbounded feeds).
    pub fn pushes_shed(&self) -> u64 {
        self.queue.shed.load(Ordering::Relaxed)
    }
}

impl<T> StreamHandle<T> {
    /// Enqueue one chunk. On a [`StreamSource::bounded`] feed a full
    /// queue blocks the producer until the consumer drains a chunk
    /// (counted once per blocking push). Pushes after
    /// [`StreamHandle::close`] are dropped (the consumer may already have
    /// observed end-of-stream).
    pub fn push(&self, chunk: Vec<T>) {
        let mut state = self.queue.state.lock().unwrap();
        if self.queue.full(&state) && !state.closed {
            self.queue.blocked.fetch_add(1, Ordering::Relaxed);
            while self.queue.full(&state) && !state.closed {
                state = self.queue.space.wait(state).unwrap();
            }
        }
        if state.closed {
            return;
        }
        state.chunks.push_back(chunk);
        drop(state);
        self.queue.ready.notify_all();
    }

    /// Non-blocking enqueue: `Err(chunk)` hands the chunk back when a
    /// [`StreamSource::bounded`] queue is full (counted as shed). Like
    /// [`StreamHandle::push`], chunks offered after close are silently
    /// dropped (`Ok`).
    pub fn try_push(&self, chunk: Vec<T>) -> Result<(), Vec<T>> {
        let mut state = self.queue.state.lock().unwrap();
        if state.closed {
            return Ok(());
        }
        if self.queue.full(&state) {
            drop(state);
            self.queue.shed.fetch_add(1, Ordering::Relaxed);
            return Err(chunk);
        }
        state.chunks.push_back(chunk);
        drop(state);
        self.queue.ready.notify_all();
        Ok(())
    }

    /// Mark end-of-stream: consumers drain what was pushed, then see
    /// `None`. Unblocks any producer waiting for space. Idempotent.
    pub fn close(&self) {
        self.queue.state.lock().unwrap().closed = true;
        self.queue.ready.notify_all();
        self.queue.space.notify_all();
    }

    /// Pushes that have blocked waiting for queue space so far.
    pub fn pushes_blocked(&self) -> u64 {
        self.queue.blocked.load(Ordering::Relaxed)
    }

    /// `try_push` chunks handed back at a full queue so far.
    pub fn pushes_shed(&self) -> u64 {
        self.queue.shed.load(Ordering::Relaxed)
    }
}

impl<T: Send> InputSource<T> for StreamSource<T> {
    fn feed(&mut self) -> Feed<'_, T> {
        let queue = Arc::clone(&self.queue);
        Feed::Stream(Box::new(move || pull_chunk(&queue)))
    }
}

static NEXT_LOG_ORDINAL: AtomicU64 = AtomicU64::new(1);

/// An append-only in-memory log with a **session-stable fingerprint
/// identity**: appending grows the log but does not change its
/// [`InputSource::fingerprint_token`], so a
/// [`Dataset::cache`](crate::api::plan::Dataset::cache) cut over the log
/// keeps hitting the same cache entry as the log grows. The cache layer
/// reads [`InputSource::append_len`] to see how far the entry is behind,
/// recomputes only the appended tail via [`InputSource::feed_tail`], and
/// merges the delta into the stored entry instead of recomputing the
/// whole prefix (counted by
/// [`CacheStats::delta_merges`](crate::cache::CacheStats)).
///
/// Open plans over it with `rt.dataset(&mut log)` (a `&mut` borrow, so
/// the log can be appended between collects).
pub struct AppendLog<T> {
    items: Vec<T>,
    token: u64,
}

impl<T> AppendLog<T> {
    /// A fresh, empty log. `label` seasons the fingerprint identity; a
    /// session ordinal keeps two same-labelled logs distinct.
    pub fn new(label: &str) -> AppendLog<T> {
        let ordinal = NEXT_LOG_ORDINAL.fetch_add(1, Ordering::Relaxed);
        AppendLog {
            items: Vec::new(),
            token: fxhash(&("append-log", label, ordinal)),
        }
    }

    /// Append items to the tail. Existing items never change — that
    /// immutability is what makes delta maintenance of cached prefixes
    /// sound.
    pub fn append(&mut self, items: impl IntoIterator<Item = T>) {
        self.items.extend(items);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The logged items, oldest first.
    pub fn items(&self) -> &[T] {
        &self.items
    }
}

impl<T> InputSource<T> for AppendLog<T> {
    fn feed(&mut self) -> Feed<'_, T> {
        Feed::Slice(&self.items)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.items.len())
    }

    fn fingerprint_token(&self) -> Option<u64> {
        Some(self.token)
    }

    fn append_len(&self) -> Option<usize> {
        Some(self.items.len())
    }

    fn feed_tail(&mut self, start: usize) -> Feed<'_, T> {
        Feed::Slice(&self.items[start.min(self.items.len())..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_source_delivers_chunks_in_order_then_ends() {
        let source = StreamSource::replay(vec![vec![1, 2], vec![], vec![3]]);
        assert_eq!(source.pull(), Some(vec![1, 2]));
        assert_eq!(source.pull(), Some(vec![3]));
        assert_eq!(source.pull(), None);
        assert_eq!(source.pull(), None);
    }

    #[test]
    fn handle_push_close_wakes_blocked_pull() {
        let (source, handle) = StreamSource::unbounded();
        let producer = std::thread::spawn(move || {
            handle.push(vec![7u32]);
            handle.push(Vec::new()); // heartbeat, not end-of-stream
            handle.push(vec![8, 9]);
            handle.close();
            handle.push(vec![10]); // after close: dropped
        });
        assert_eq!(source.pull(), Some(vec![7]));
        assert_eq!(source.pull(), Some(vec![8, 9]));
        assert_eq!(source.pull(), None);
        producer.join().unwrap();
    }

    #[test]
    fn bounded_try_push_sheds_at_capacity() {
        let (source, handle) = StreamSource::bounded(2);
        assert!(handle.try_push(vec![1]).is_ok());
        assert!(handle.try_push(vec![2]).is_ok());
        let back = handle.try_push(vec![3]).unwrap_err();
        assert_eq!(back, vec![3]);
        assert_eq!(source.pushes_shed(), 1);
        assert_eq!(source.pushes_blocked(), 0);
        // Draining one chunk frees a slot for the handed-back chunk.
        assert_eq!(source.pull(), Some(vec![1]));
        assert!(handle.try_push(back).is_ok());
        handle.close();
        assert_eq!(source.pull(), Some(vec![2]));
        assert_eq!(source.pull(), Some(vec![3]));
        assert_eq!(source.pull(), None);
    }

    #[test]
    fn bounded_push_blocks_until_consumer_drains() {
        let (source, handle) = StreamSource::bounded(1);
        handle.push(vec![1u32]);
        let h = handle.clone();
        let producer = std::thread::spawn(move || {
            h.push(vec![2]); // queue full: must block until a pull
            h.close();
        });
        // Nothing is pulling yet, so the producer must block (and count
        // the block) before it can enqueue.
        let t0 = std::time::Instant::now();
        while source.pushes_blocked() == 0 {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "producer never reached the full queue"
            );
            std::thread::yield_now();
        }
        assert_eq!(source.pull(), Some(vec![1]));
        assert_eq!(source.pull(), Some(vec![2]));
        assert_eq!(source.pull(), None);
        producer.join().unwrap();
        assert_eq!(source.pushes_blocked(), 1);
        assert_eq!(source.pushes_shed(), 0);
    }

    #[test]
    fn append_log_keeps_token_and_exposes_tail() {
        let mut log: AppendLog<i64> = AppendLog::new("t");
        let before = log.fingerprint_token();
        log.append([1, 2, 3]);
        assert_eq!(log.fingerprint_token(), before);
        assert_eq!(log.append_len(), Some(3));
        log.append([4, 5]);
        match log.feed_tail(3) {
            Feed::Slice(tail) => assert_eq!(tail, &[4, 5]),
            Feed::Stream(_) => panic!("append log tails are slices"),
        }
        // Out-of-range start clamps to empty rather than panicking.
        match log.feed_tail(99) {
            Feed::Slice(tail) => assert!(tail.is_empty()),
            Feed::Stream(_) => panic!("append log tails are slices"),
        }
    }

    #[test]
    fn two_logs_with_same_label_have_distinct_tokens() {
        let a: AppendLog<i64> = AppendLog::new("dup");
        let b: AppendLog<i64> = AppendLog::new("dup");
        assert_ne!(a.fingerprint_token(), b.fingerprint_token());
    }
}
