//! Unified session tracing: a low-overhead span/event timeline plus the
//! [`MetricsRegistry`] (see [`metrics`]) behind one `Runtime`-owned
//! handle.
//!
//! The paper's optimizer exists because someone *observed* runtime
//! behavior — map-phase time, GC pressure — and fed it back into the
//! framework. This module is that observation layer for the whole
//! session: every subsystem (planner lowering, admission, batch
//! scheduling, per-shard task execution, the two-tier cache, streaming
//! panes, the simulated heap) records spans and instant events into
//! per-thread lock-free ring buffers owned by one [`Tracer`].
//!
//! # Design constraints
//!
//! * **Tracing off ≈ one atomic load.** [`Tracer::span`] reads a single
//!   `AtomicBool`; when disabled it returns an inert guard without
//!   touching the clock, allocating, or taking any lock.
//! * **No locks on the hot path when enabled.** Each thread records
//!   into its own single-producer ring ([`Ring`]); the only lock is
//!   taken once per thread at ring registration. Slots carry per-slot
//!   sequence numbers (seqlock style) so the exporter can snapshot from
//!   another thread and skip torn slots instead of blocking writers.
//! * **Bounded, drop-oldest.** Rings hold [`Tracer::capacity`] events;
//!   the wrapping write cursor overwrites the oldest, and
//!   [`Ring::dropped`] counts the overwritten events so an export can
//!   say "this timeline is missing its head".
//! * **Complete events, not begin/end pairs.** A span is recorded as
//!   one Chrome `"X"` event at guard drop (start + duration), so a
//!   dropped slot loses one span — never an unmatched begin.
//!
//! # Export
//!
//! [`Tracer::export_chrome_trace`] emits the Chrome `trace_event` JSON
//! array format (load the file in `chrome://tracing` or
//! [Perfetto](https://ui.perfetto.dev)) with `pid` = session and `tid` =
//! worker index (worker threads pre-register their id; other threads get
//! stable synthetic tids). [`Tracer::summary_since`] distills the same
//! ring contents into a [`TraceSummary`] for
//! [`PlanReport::trace`](crate::api::plan::PlanReport).

pub mod metrics;

pub use metrics::{MetricValue, MetricsRegistry, MetricsSnapshot};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// What a span or instant event describes. The two `u64` args on each
/// event are kind-specific (documented per variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// Whole-plan lowering (a = stage count, b = 1 if adaptive).
    PlanLower,
    /// One adaptive decision applied to a collect (a = decision index
    /// within the plan's [`AdaptationReport`](crate::stats::AdaptationReport)).
    AdaptiveDecision,
    /// An admission verdict (a = 1 admitted / 0 rejected, b = tenant).
    Admission,
    /// One tagged batch from submit to drain (a = batch id,
    /// b = executed tasks).
    Batch,
    /// One task executed by a worker (a = batch id, b = 1 if panicked).
    Task,
    /// A map phase of one reduce-shaped stage (a = batch id, b = chunks).
    MapPhase,
    /// A reduce/finalize phase (a = batch id, b = shards).
    ReducePhase,
    /// Cache read served from a ready hot-tier entry (confirmed after
    /// the reader's typed downcast; no args).
    CacheHit,
    /// Cache read that claimed a materialization (a = fingerprint).
    CacheMiss,
    /// Cache read that waited on an in-flight claim and shared its
    /// result (no args).
    CacheShared,
    /// A claimed prefix computed and inserted (a = bytes, b = items);
    /// the duration is the producing plan's measured recompute time.
    CacheMaterialize,
    /// A hot entry demoted to the spill tier (a = bytes, b = items).
    CacheSpill,
    /// A spilled entry reloaded into the hot tier (a = bytes,
    /// b = items).
    CacheReload,
    /// A spilled entry aged out: decayed value below reload cost
    /// (a = bytes, b = items).
    CacheAgeOut,
    /// One window fired: its panes merged and finalized (a = window end
    /// event-time, b = panes merged).
    PaneFire,
    /// One pane's holders merged into a firing window (a = pane start).
    PaneMerge,
    /// A heap cohort registered (a = cohort slot).
    CohortAlloc,
    /// A heap cohort bulk-released (a = cohort slot, b = old-gen bytes
    /// turned to garbage).
    CohortRelease,
    /// A minor collection (a = promoted bytes, b = live young after).
    GcMinor,
    /// A major collection (a = live bytes scanned).
    GcMajor,
    /// Promotion pressure crossed the major-GC trigger
    /// (a = promoted-since-major bytes).
    GcPressure,
}

impl SpanKind {
    /// Stable display name (Chrome trace `name`).
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::PlanLower => "plan.lower",
            SpanKind::AdaptiveDecision => "plan.adaptive_decision",
            SpanKind::Admission => "govern.admission",
            SpanKind::Batch => "pool.batch",
            SpanKind::Task => "pool.task",
            SpanKind::MapPhase => "flow.map_phase",
            SpanKind::ReducePhase => "flow.reduce_phase",
            SpanKind::CacheHit => "cache.hit",
            SpanKind::CacheMiss => "cache.miss",
            SpanKind::CacheShared => "cache.shared_in_flight",
            SpanKind::CacheMaterialize => "cache.materialize",
            SpanKind::CacheSpill => "cache.spill",
            SpanKind::CacheReload => "cache.reload",
            SpanKind::CacheAgeOut => "cache.age_out",
            SpanKind::PaneFire => "stream.pane_fire",
            SpanKind::PaneMerge => "stream.pane_merge",
            SpanKind::CohortAlloc => "memsim.cohort_alloc",
            SpanKind::CohortRelease => "memsim.cohort_release",
            SpanKind::GcMinor => "memsim.minor_gc",
            SpanKind::GcMajor => "memsim.major_gc",
            SpanKind::GcPressure => "memsim.gc_pressure",
        }
    }

    /// Coarse phase bucket (Chrome trace `cat`, [`TraceSummary`] rows).
    pub fn phase(self) -> &'static str {
        match self {
            SpanKind::PlanLower | SpanKind::AdaptiveDecision => "plan",
            SpanKind::Admission => "govern",
            SpanKind::Batch | SpanKind::Task => "schedule",
            SpanKind::MapPhase | SpanKind::ReducePhase => "flow",
            SpanKind::CacheHit
            | SpanKind::CacheMiss
            | SpanKind::CacheShared
            | SpanKind::CacheMaterialize
            | SpanKind::CacheSpill
            | SpanKind::CacheReload
            | SpanKind::CacheAgeOut => "cache",
            SpanKind::PaneFire | SpanKind::PaneMerge => "stream",
            SpanKind::CohortAlloc
            | SpanKind::CohortRelease
            | SpanKind::GcMinor
            | SpanKind::GcMajor
            | SpanKind::GcPressure => "memsim",
        }
    }

    fn from_code(code: u64) -> Option<SpanKind> {
        use SpanKind::*;
        const ALL: [SpanKind; 21] = [
            PlanLower,
            AdaptiveDecision,
            Admission,
            Batch,
            Task,
            MapPhase,
            ReducePhase,
            CacheHit,
            CacheMiss,
            CacheShared,
            CacheMaterialize,
            CacheSpill,
            CacheReload,
            CacheAgeOut,
            PaneFire,
            PaneMerge,
            CohortAlloc,
            CohortRelease,
            GcMinor,
            GcMajor,
            GcPressure,
        ];
        ALL.get(code as usize).copied()
    }

    fn code(self) -> u64 {
        use SpanKind::*;
        match self {
            PlanLower => 0,
            AdaptiveDecision => 1,
            Admission => 2,
            Batch => 3,
            Task => 4,
            MapPhase => 5,
            ReducePhase => 6,
            CacheHit => 7,
            CacheMiss => 8,
            CacheShared => 9,
            CacheMaterialize => 10,
            CacheSpill => 11,
            CacheReload => 12,
            CacheAgeOut => 13,
            PaneFire => 14,
            PaneMerge => 15,
            CohortAlloc => 16,
            CohortRelease => 17,
            GcMinor => 18,
            GcMajor => 19,
            GcPressure => 20,
        }
    }
}

/// One recorded span (`dur_us > 0`) or instant event (`dur_us == 0`).
/// Timestamps are microseconds since the tracer's epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub kind: SpanKind,
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific argument (see [`SpanKind`] variant docs).
    pub a: u64,
    /// Second kind-specific argument.
    pub b: u64,
}

/// Words per ring slot: per-slot sequence + the five event words.
const SLOT_WORDS: usize = 6;

/// One thread's bounded single-producer event ring. Only the owning
/// thread writes; the exporter reads concurrently and skips slots whose
/// sequence word shows a write in progress (seqlock per slot).
struct Ring {
    /// Chrome `tid`: the worker id for pool threads (pre-registered via
    /// [`set_thread_tid`]), a stable synthetic id otherwise.
    tid: u64,
    name: String,
    /// Monotonic write cursor; slot index is `head % capacity`.
    head: AtomicU64,
    /// `capacity * SLOT_WORDS` atomics: per slot `[seq, kind, start_us,
    /// dur_us, a, b]`. `seq == 2*gen + 2` marks generation `gen` fully
    /// written; odd values mark a write in progress.
    slots: Box<[AtomicU64]>,
    capacity: usize,
}

impl Ring {
    fn new(tid: u64, name: String, capacity: usize) -> Ring {
        let words = (0..capacity * SLOT_WORDS)
            .map(|_| AtomicU64::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring {
            tid,
            name,
            head: AtomicU64::new(0),
            slots: words,
            capacity,
        }
    }

    /// Record one event. Caller must be the owning thread.
    fn push(&self, kind: SpanKind, start_us: u64, dur_us: u64, a: u64, b: u64) {
        let gen = self.head.load(Ordering::Relaxed);
        let base = (gen as usize % self.capacity) * SLOT_WORDS;
        let s = &self.slots;
        s[base].store(2 * gen + 1, Ordering::Release);
        s[base + 1].store(kind.code(), Ordering::Relaxed);
        s[base + 2].store(start_us, Ordering::Relaxed);
        s[base + 3].store(dur_us, Ordering::Relaxed);
        s[base + 4].store(a, Ordering::Relaxed);
        s[base + 5].store(b, Ordering::Relaxed);
        s[base].store(2 * gen + 2, Ordering::Release);
        self.head.store(gen + 1, Ordering::Release);
    }

    /// Events overwritten so far (drop-oldest).
    fn dropped(&self) -> u64 {
        self.head
            .load(Ordering::Acquire)
            .saturating_sub(self.capacity as u64)
    }

    /// Snapshot the resident events, oldest first, skipping torn slots.
    fn drain(&self) -> Vec<Event> {
        let head = self.head.load(Ordering::Acquire);
        let start = head.saturating_sub(self.capacity as u64);
        let mut out = Vec::with_capacity((head - start) as usize);
        for gen in start..head {
            let base = (gen as usize % self.capacity) * SLOT_WORDS;
            let s = &self.slots;
            if s[base].load(Ordering::Acquire) != 2 * gen + 2 {
                continue; // torn or already overwritten by a newer lap
            }
            let kind = s[base + 1].load(Ordering::Relaxed);
            let ev = Event {
                kind: match SpanKind::from_code(kind) {
                    Some(k) => k,
                    None => continue,
                },
                start_us: s[base + 2].load(Ordering::Relaxed),
                dur_us: s[base + 3].load(Ordering::Relaxed),
                a: s[base + 4].load(Ordering::Relaxed),
                b: s[base + 5].load(Ordering::Relaxed),
            };
            if s[base].load(Ordering::Acquire) != 2 * gen + 2 {
                continue; // overwritten while we read
            }
            out.push(ev);
        }
        out
    }
}

thread_local! {
    /// Per-thread `(tracer id, ring)` registry — one ring per tracer a
    /// thread has recorded into.
    static THREAD_RINGS: RefCell<Vec<(u64, Arc<Ring>)>> = const { RefCell::new(Vec::new()) };
    /// Worker-id override installed by pool worker threads so their
    /// Chrome `tid` is the worker index, not a synthetic id.
    static THREAD_TID: std::cell::Cell<Option<u64>> = const { std::cell::Cell::new(None) };
}

/// Pin the calling thread's trace `tid` (worker threads call this once
/// with their worker index before recording anything).
pub fn set_thread_tid(tid: u64) {
    THREAD_TID.with(|t| t.set(Some(tid)));
}

static NEXT_TRACER_ID: AtomicU64 = AtomicU64::new(1);

/// Synthetic tid base for threads that never called [`set_thread_tid`]
/// (drivers, tests): far above any plausible worker index.
const SYNTHETIC_TID_BASE: u64 = 1000;

struct TracerInner {
    id: u64,
    enabled: AtomicBool,
    epoch: Instant,
    capacity: usize,
    rings: Mutex<Vec<Arc<Ring>>>,
}

/// The session tracer: cheap to clone (`Arc` inner), safe to record
/// into from any thread. Disabled by default — [`Tracer::set_enabled`]
/// or the `MR4R_TRACE=1` environment switch turn it on.
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<TracerInner>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    /// Default ring capacity per thread, in events. Override with
    /// `MR4R_TRACE_CAPACITY`.
    pub const DEFAULT_CAPACITY: usize = 16 * 1024;

    pub fn new() -> Tracer {
        let capacity = std::env::var("MR4R_TRACE_CAPACITY")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&c| c > 0)
            .unwrap_or(Self::DEFAULT_CAPACITY);
        Tracer::with_capacity(capacity)
    }

    /// A tracer with an explicit per-thread ring capacity (events).
    pub fn with_capacity(capacity: usize) -> Tracer {
        Tracer {
            inner: Arc::new(TracerInner {
                id: NEXT_TRACER_ID.fetch_add(1, Ordering::Relaxed),
                enabled: AtomicBool::new(false),
                epoch: Instant::now(),
                capacity: capacity.max(16),
                rings: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Per-thread ring capacity, events.
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Whether events are being recorded — the one hot-path check.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on or off (off is the default).
    pub fn set_enabled(&self, on: bool) {
        self.inner.enabled.store(on, Ordering::Relaxed);
    }

    /// Microseconds since the tracer epoch.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.inner.epoch.elapsed().as_micros() as u64
    }

    /// Open a span; the event is recorded when the guard drops. When
    /// tracing is off this is one atomic load and an inert guard.
    #[inline]
    pub fn span(&self, kind: SpanKind, a: u64, b: u64) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard {
                tracer: None,
                kind,
                start_us: 0,
                a,
                b,
            };
        }
        SpanGuard {
            tracer: Some(self),
            kind,
            start_us: self.now_us(),
            a,
            b,
        }
    }

    /// Record an instant event (duration 0). No-op when disabled.
    #[inline]
    pub fn instant(&self, kind: SpanKind, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        let now = self.now_us();
        self.record(kind, now, 0, a, b);
    }

    /// Record a span that started at `start_us` (from [`Tracer::now_us`])
    /// and ends now. No-op when disabled.
    #[inline]
    pub fn record_since(&self, kind: SpanKind, start_us: u64, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        let dur = self.now_us().saturating_sub(start_us);
        self.record(kind, start_us, dur, a, b);
    }

    /// Record a span with an externally measured duration ending now —
    /// for subsystems that already hold a stopwatch value (e.g. the
    /// cache's materialization wall time, the memsim's injected pauses).
    #[inline]
    pub fn record_with_dur(&self, kind: SpanKind, dur_secs: f64, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        let dur_us = (dur_secs.max(0.0) * 1e6) as u64;
        let now = self.now_us();
        self.record(kind, now.saturating_sub(dur_us), dur_us, a, b);
    }

    fn record(&self, kind: SpanKind, start_us: u64, dur_us: u64, a: u64, b: u64) {
        THREAD_RINGS.with(|cell| {
            let mut rings = cell.borrow_mut();
            let ring = match rings.iter().find(|(id, _)| *id == self.inner.id) {
                Some((_, r)) => Arc::clone(r),
                None => {
                    let mut registry = self.inner.rings.lock().unwrap_or_else(|e| e.into_inner());
                    let tid = THREAD_TID
                        .with(|t| t.get())
                        .unwrap_or(SYNTHETIC_TID_BASE + registry.len() as u64);
                    let name = std::thread::current()
                        .name()
                        .unwrap_or("thread")
                        .to_string();
                    let ring = Arc::new(Ring::new(tid, name, self.inner.capacity));
                    registry.push(Arc::clone(&ring));
                    drop(registry);
                    rings.push((self.inner.id, Arc::clone(&ring)));
                    ring
                }
            };
            ring.push(kind, start_us, dur_us, a, b);
        });
    }

    /// Snapshot every thread's resident events (plus tid / thread name /
    /// dropped count), oldest first within each thread.
    pub fn snapshot(&self) -> Vec<ThreadEvents> {
        let rings = self.inner.rings.lock().unwrap_or_else(|e| e.into_inner());
        rings
            .iter()
            .map(|r| ThreadEvents {
                tid: r.tid,
                name: r.name.clone(),
                dropped: r.dropped(),
                events: r.drain(),
            })
            .collect()
    }

    /// Total events recorded of one kind (across all threads, resident
    /// only — dropped events are gone).
    pub fn count(&self, kind: SpanKind) -> u64 {
        self.snapshot()
            .iter()
            .flat_map(|t| t.events.iter())
            .filter(|e| e.kind == kind)
            .count() as u64
    }

    /// Total resident events across all threads.
    pub fn total_events(&self) -> u64 {
        self.snapshot().iter().map(|t| t.events.len() as u64).sum()
    }

    /// Total events lost to ring overwrites.
    pub fn dropped(&self) -> u64 {
        let rings = self.inner.rings.lock().unwrap_or_else(|e| e.into_inner());
        rings.iter().map(|r| r.dropped()).sum()
    }

    /// The full session timeline in Chrome `trace_event` JSON object
    /// format: load the serialized string in `chrome://tracing` or
    /// Perfetto. `pid` is the session (always 1), `tid` the worker.
    pub fn export_chrome_trace(&self) -> Json {
        let mut events = Json::arr();
        for t in self.snapshot() {
            // Thread-name metadata record so the UI labels rows.
            events.push(
                Json::obj()
                    .set("name", "thread_name")
                    .set("ph", "M")
                    .set("pid", 1u64)
                    .set("tid", t.tid)
                    .set("args", Json::obj().set("name", t.name.as_str())),
            );
            for e in &t.events {
                let args = Json::obj().set("a", e.a).set("b", e.b);
                let mut obj = Json::obj()
                    .set("name", e.kind.label())
                    .set("cat", e.kind.phase())
                    .set("ph", if e.dur_us > 0 { "X" } else { "i" })
                    .set("ts", e.start_us)
                    .set("pid", 1u64)
                    .set("tid", t.tid);
                if e.dur_us > 0 {
                    obj = obj.set("dur", e.dur_us);
                } else {
                    obj = obj.set("s", "t");
                }
                events.push(obj.set("args", args));
            }
        }
        Json::obj()
            .set("traceEvents", events)
            .set("displayTimeUnit", "ms")
            .set("otherData", Json::obj().set("dropped_events", self.dropped()))
    }

    /// Summarize every event in the window `[since_us, now]` — what the
    /// plan epilogue attaches to
    /// [`PlanReport::trace`](crate::api::plan::PlanReport). Under
    /// concurrent plans the window also contains other plans' events, so
    /// the summary is an *attribution estimate*, exact when one plan
    /// runs at a time.
    pub fn summary_since(&self, since_us: u64) -> TraceSummary {
        let mut summary = TraceSummary {
            dropped: self.dropped(),
            ..TraceSummary::default()
        };
        let mut busy_per_tid: Vec<(u64, f64)> = Vec::new();
        for t in self.snapshot() {
            let mut tid_busy = 0.0f64;
            for e in t.events.iter().filter(|e| e.start_us >= since_us) {
                summary.spans += 1;
                let secs = e.dur_us as f64 / 1e6;
                let phase = e.kind.phase();
                match summary.phases.iter_mut().find(|p| p.phase == phase) {
                    Some(p) => {
                        p.count += 1;
                        p.busy_secs += secs;
                    }
                    None => summary.phases.push(PhaseSummary {
                        phase,
                        count: 1,
                        busy_secs: secs,
                    }),
                }
                // Worker-busy kinds only: the Batch span is a driver's
                // submit-to-drain wait and would double-count its tasks.
                if matches!(
                    e.kind,
                    SpanKind::Task
                        | SpanKind::CacheMaterialize
                        | SpanKind::GcMinor
                        | SpanKind::GcMajor
                        | SpanKind::PaneFire
                ) {
                    tid_busy += secs;
                }
            }
            if tid_busy > 0.0 {
                busy_per_tid.push((t.tid, tid_busy));
            }
        }
        summary.phases.sort_by(|x, y| x.phase.cmp(y.phase));
        summary.critical_path_secs = busy_per_tid.iter().map(|(_, b)| *b).fold(0.0, f64::max);
        summary
    }
}

/// One thread's snapshot slice (see [`Tracer::snapshot`]).
#[derive(Clone, Debug)]
pub struct ThreadEvents {
    pub tid: u64,
    pub name: String,
    pub dropped: u64,
    pub events: Vec<Event>,
}

/// Per-phase rollup inside a [`TraceSummary`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseSummary {
    /// Phase bucket ([`SpanKind::phase`]).
    pub phase: &'static str,
    /// Events recorded in the window.
    pub count: u64,
    /// Σ span durations, seconds (instants contribute 0).
    pub busy_secs: f64,
}

/// Span-count and wall-time rollup of a trace window — the
/// [`PlanReport`](crate::api::plan::PlanReport) attachment.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSummary {
    /// Events in the window (spans + instants).
    pub spans: u64,
    /// Session-wide events lost to ring overwrites (not window-scoped:
    /// a nonzero value means *some* timeline head is missing).
    pub dropped: u64,
    /// Per-phase counts and busy time, sorted by phase name.
    pub phases: Vec<PhaseSummary>,
    /// Longest per-thread busy time in the window — a lower-bound
    /// critical-path estimate (a thread can't finish before its own
    /// recorded work).
    pub critical_path_secs: f64,
}

impl TraceSummary {
    /// The rollup row for one phase bucket, if any event landed there.
    pub fn phase(&self, name: &str) -> Option<&PhaseSummary> {
        self.phases.iter().find(|p| p.phase == name)
    }
}

/// A pending span; records one complete event at drop. Inert (no clock
/// read, nothing recorded) when the tracer was disabled at creation.
pub struct SpanGuard<'a> {
    tracer: Option<&'a Tracer>,
    kind: SpanKind,
    start_us: u64,
    a: u64,
    b: u64,
}

impl SpanGuard<'_> {
    /// Update the span's args before it records (e.g. a batch span
    /// learning its executed-task count at drain).
    pub fn set_args(&mut self, a: u64, b: u64) {
        self.a = a;
        self.b = b;
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(t) = self.tracer {
            let dur = t.now_us().saturating_sub(self.start_us);
            t.record(self.kind, self.start_us, dur, self.a, self.b);
        }
    }
}

/// The observability handle subsystems attach: the session tracer plus
/// its metrics registry. Cloneable; attached once per subsystem via
/// `OnceLock` (the same late-binding pattern as
/// [`MaterializationCache::attach_cost_feed`](crate::cache::MaterializationCache::attach_cost_feed)).
#[derive(Clone)]
pub struct Obs {
    pub tracer: Tracer,
    pub metrics: Arc<MetricsRegistry>,
}

impl Obs {
    pub fn new() -> Obs {
        Obs {
            tracer: Tracer::new(),
            metrics: Arc::new(MetricsRegistry::new()),
        }
    }
}

impl Default for Obs {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        assert!(!t.enabled());
        {
            let _s = t.span(SpanKind::Task, 1, 2);
        }
        t.instant(SpanKind::CacheHit, 0, 0);
        t.record_with_dur(SpanKind::GcMinor, 0.5, 0, 0);
        assert_eq!(t.total_events(), 0);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn spans_and_instants_record_with_args() {
        let t = Tracer::new();
        t.set_enabled(true);
        {
            let mut s = t.span(SpanKind::Batch, 7, 0);
            s.set_args(7, 42);
        }
        t.instant(SpanKind::CacheMiss, 9, 0);
        let snap = t.snapshot();
        let events: Vec<&Event> = snap.iter().flat_map(|t| t.events.iter()).collect();
        assert_eq!(events.len(), 2);
        let batch = events.iter().find(|e| e.kind == SpanKind::Batch).unwrap();
        assert_eq!((batch.a, batch.b), (7, 42));
        let miss = events.iter().find(|e| e.kind == SpanKind::CacheMiss).unwrap();
        assert_eq!(miss.dur_us, 0);
        assert_eq!(t.count(SpanKind::CacheMiss), 1);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let t = Tracer::with_capacity(16);
        t.set_enabled(true);
        for i in 0..40u64 {
            t.instant(SpanKind::Task, i, 0);
        }
        assert_eq!(t.total_events(), 16);
        assert_eq!(t.dropped(), 24);
        // Survivors are the newest events.
        let snap = t.snapshot();
        let first = snap[0].events.first().unwrap();
        assert_eq!(first.a, 24);
    }

    #[test]
    fn concurrent_writers_keep_per_thread_rings() {
        let t = Tracer::new();
        t.set_enabled(true);
        std::thread::scope(|s| {
            for w in 0..4u64 {
                let t = t.clone();
                s.spawn(move || {
                    set_thread_tid(w);
                    for _ in 0..100 {
                        t.instant(SpanKind::Task, w, 0);
                    }
                });
            }
        });
        let snap = t.snapshot();
        assert_eq!(snap.len(), 4);
        let mut tids: Vec<u64> = snap.iter().map(|r| r.tid).collect();
        tids.sort();
        assert_eq!(tids, vec![0, 1, 2, 3]);
        assert_eq!(t.count(SpanKind::Task), 400);
    }

    #[test]
    fn exporter_is_safe_under_concurrent_writes() {
        let t = Tracer::with_capacity(64);
        t.set_enabled(true);
        std::thread::scope(|s| {
            let writer = t.clone();
            s.spawn(move || {
                for i in 0..20_000u64 {
                    writer.instant(SpanKind::Task, i, i);
                }
            });
            for _ in 0..50 {
                // Every snapshotted event must be internally consistent
                // (a == b by construction; torn slots are skipped).
                for te in t.snapshot() {
                    for e in te.events {
                        assert_eq!(e.a, e.b, "torn slot leaked");
                    }
                }
            }
        });
    }

    #[test]
    fn chrome_export_shape() {
        let t = Tracer::new();
        t.set_enabled(true);
        {
            let _s = t.span(SpanKind::PlanLower, 3, 1);
        }
        t.instant(SpanKind::Admission, 1, 0);
        let json = t.export_chrome_trace().to_string();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"plan.lower\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"pid\":1"));
        assert!(json.contains("\"displayTimeUnit\":\"ms\""));
    }

    #[test]
    fn summary_rolls_up_phases_and_critical_path() {
        let t = Tracer::new();
        t.set_enabled(true);
        t.record_with_dur(SpanKind::Task, 0.010, 1, 0);
        t.record_with_dur(SpanKind::Task, 0.020, 1, 0);
        t.instant(SpanKind::CacheHit, 0, 0);
        let s = t.summary_since(0);
        assert_eq!(s.spans, 3);
        let sched = s.phase("schedule").unwrap();
        assert_eq!(sched.count, 2);
        assert!(sched.busy_secs >= 0.029, "busy {}", sched.busy_secs);
        assert_eq!(s.phase("cache").unwrap().count, 1);
        assert!(s.critical_path_secs >= 0.029);
        // A later window excludes the earlier events.
        let later = t.summary_since(t.now_us() + 1_000_000);
        assert_eq!(later.spans, 0);
    }
}
