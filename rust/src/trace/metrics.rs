//! The queryable metrics registry: named counters, gauges, and
//! log-bucketed histograms behind one
//! [`Runtime::metrics`](crate::api::Runtime::metrics) snapshot.
//!
//! Naming scheme: `<subsystem>.<measure>[_<unit>]` — e.g.
//! `pool.task_us` (task latency histogram, microseconds),
//! `pool.queue_depth` (gauge), `cache.reload_us`, `govern.admission_wait_us`,
//! `stream.watermark_lag_ms`. Instruments are created on first use and
//! live for the registry's lifetime; publishers hold the returned `Arc`
//! so steady-state recording is a couple of relaxed atomic ops with no
//! map lookup.
//!
//! Histograms are log2-bucketed (`bucket = ⌈log2(v+1)⌉`, 64 buckets):
//! coarse but constant-space and lock-free, good enough for the
//! p50/p95/p99 tail shape the scoreboard reports. Percentile estimates
//! return the upper bound of the bucket the rank falls in.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    /// Set only if `n` is larger (high-watermark gauges).
    pub fn set_max(&self, n: u64) {
        self.v.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log2 buckets: values up to 2^63, plus bucket 0 for value 0.
const BUCKETS: usize = 64;

/// A lock-free log2-bucketed histogram.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    fn bucket_of(value: u64) -> usize {
        (64 - value.leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Upper bound of a bucket (the percentile estimate it reports).
    fn bucket_upper(bucket: usize) -> u64 {
        if bucket == 0 {
            0
        } else {
            (1u64 << bucket).saturating_sub(1).max(1)
        }
    }

    pub fn record(&self, value: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.buckets[Self::bucket_of(value)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration in microseconds.
    pub fn record_secs(&self, secs: f64) {
        self.record((secs.max(0.0) * 1e6) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Estimated `q`-quantile (`0.0..=1.0`): upper bound of the bucket
    /// the rank lands in. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(BUCKETS - 1)
    }
}

enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Instrument {
    fn type_name(&self) -> &'static str {
        match self {
            Instrument::Counter(_) => "counter",
            Instrument::Gauge(_) => "gauge",
            Instrument::Histogram(_) => "histogram",
        }
    }
}

/// The session metrics registry: get-or-create named instruments,
/// snapshot them all at once. Owned by
/// [`Runtime`](crate::api::Runtime); every subsystem publishes into the
/// same instance via the attached [`Obs`](super::Obs) handle.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<HashMap<String, Instrument>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        Self::default()
    }

    /// Get or create a counter.
    ///
    /// # Panics
    ///
    /// If `name` is already registered as a different instrument type —
    /// a naming bug that should fail loudly in tests.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let entry = inner
            .entry(name.to_string())
            .or_insert_with(|| Instrument::Counter(Arc::new(Counter::default())));
        match entry {
            Instrument::Counter(c) => Arc::clone(c),
            other => panic!("metric `{name}` is a {}, not a counter", other.type_name()),
        }
    }

    /// Get or create a gauge (panics on a type conflict, like
    /// [`MetricsRegistry::counter`]).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let entry = inner
            .entry(name.to_string())
            .or_insert_with(|| Instrument::Gauge(Arc::new(Gauge::default())));
        match entry {
            Instrument::Gauge(g) => Arc::clone(g),
            other => panic!("metric `{name}` is a {}, not a gauge", other.type_name()),
        }
    }

    /// Get or create a histogram (panics on a type conflict, like
    /// [`MetricsRegistry::counter`]).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let entry = inner
            .entry(name.to_string())
            .or_insert_with(|| Instrument::Histogram(Arc::new(Histogram::default())));
        match entry {
            Instrument::Histogram(h) => Arc::clone(h),
            other => panic!("metric `{name}` is a {}, not a histogram", other.type_name()),
        }
    }

    /// Registered instrument count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A consistent point-in-time view of every instrument, sorted by
    /// name.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut entries: Vec<MetricEntry> = inner
            .iter()
            .map(|(name, inst)| MetricEntry {
                name: name.clone(),
                value: match inst {
                    Instrument::Counter(c) => MetricValue::Counter(c.get()),
                    Instrument::Gauge(g) => MetricValue::Gauge(g.get()),
                    Instrument::Histogram(h) => MetricValue::Histogram {
                        count: h.count(),
                        sum: h.sum(),
                        p50: h.quantile(0.50),
                        p95: h.quantile(0.95),
                        p99: h.quantile(0.99),
                    },
                },
            })
            .collect();
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        MetricsSnapshot { entries }
    }
}

/// One instrument's snapshotted value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Histogram {
        count: u64,
        sum: u64,
        p50: u64,
        p95: u64,
        p99: u64,
    },
}

/// One named instrument in a snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricEntry {
    pub name: String,
    pub value: MetricValue,
}

/// A point-in-time view of the whole registry, sorted by name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub entries: Vec<MetricEntry>,
}

impl MetricsSnapshot {
    /// Look up one instrument by name.
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| &e.value)
    }

    /// Render as a JSON object: counters and gauges as numbers,
    /// histograms as `{count, sum, p50, p95, p99}` objects.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for e in &self.entries {
            obj = match &e.value {
                MetricValue::Counter(v) | MetricValue::Gauge(v) => obj.set(&e.name, *v),
                MetricValue::Histogram {
                    count,
                    sum,
                    p50,
                    p95,
                    p99,
                } => obj.set(
                    &e.name,
                    Json::obj()
                        .set("count", *count)
                        .set("sum", *sum)
                        .set("p50", *p50)
                        .set("p95", *p95)
                        .set("p99", *p99),
                ),
            };
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let m = MetricsRegistry::new();
        let c = m.counter("cache.reloads");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same name returns the same instrument.
        assert_eq!(m.counter("cache.reloads").get(), 5);
        let g = m.gauge("pool.queue_depth");
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
        g.set_max(2);
        assert_eq!(g.get(), 3);
        assert_eq!(m.len(), 2);
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn type_conflicts_fail_loudly() {
        let m = MetricsRegistry::new();
        m.counter("x");
        m.gauge("x");
    }

    #[test]
    fn histogram_percentiles_bracket_the_distribution() {
        let m = MetricsRegistry::new();
        let h = m.histogram("pool.task_us");
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        // Log2 buckets: estimates are upper bounds of the right bucket.
        assert!((511..=1023).contains(&p50), "p50 {p50}");
        assert!(p99 >= 1000, "p99 {p99}");
        assert!(p50 <= p99);
        assert_eq!(m.histogram("pool.task_us").count(), 1000);
        // Empty histogram reports zeros.
        assert_eq!(m.histogram("empty").quantile(0.5), 0);
    }

    #[test]
    fn snapshot_sorts_and_serializes() {
        let m = MetricsRegistry::new();
        m.counter("b.count").add(2);
        m.gauge("a.depth").set(9);
        m.histogram("c.lat_us").record(100);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["a.depth", "b.count", "c.lat_us"]);
        assert_eq!(snap.get("b.count"), Some(&MetricValue::Counter(2)));
        let json = snap.to_json().to_string();
        assert!(json.contains("\"a.depth\":9"));
        assert!(json.contains("\"count\":1"));
        assert!(json.contains("\"p95\""));
    }

    #[test]
    fn concurrent_publishers_do_not_lose_counts() {
        let m = Arc::new(MetricsRegistry::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    let c = m.counter("hits");
                    let h = m.histogram("lat");
                    for i in 0..1000u64 {
                        c.inc();
                        h.record(i);
                    }
                });
            }
        });
        assert_eq!(m.counter("hits").get(), 8000);
        assert_eq!(m.histogram("lat").count(), 8000);
    }
}
