//! The optimizer feedback store — adaptive, statistics-fed
//! re-optimization.
//!
//! The whole-plan optimizer ([`crate::coordinator::planner`]) decides
//! fusion, combining, and sharding *statically*. This module closes the
//! loop: after every plan collect, the executor records what actually
//! happened — map-phase cardinalities, per-filter selectivities, a
//! key-frequency sketch for skew, holder growth, wall time — into a
//! [`StatsStore`] owned by the session [`Runtime`](crate::api::Runtime),
//! keyed by the same structural prefix fingerprints the materialization
//! cache uses ([`crate::cache::fingerprint`]). The *next* lowering of an
//! identical plan prefix consults the store and may:
//!
//! * **reorder filters** — compose buffered consecutive filter
//!   predicates cheapest-first (ascending measured selectivity), so
//!   low-pass filters run before expensive ones ([`filter_order`]);
//! * **pick shard counts from observed cardinality** — a stage whose
//!   last run produced few distinct keys gets a smaller collector
//!   ([`StageAdapt::shard_override`]);
//! * **switch declared-vs-list keyed flows** — when measured holder
//!   growth contradicts the static choice (in-map combining collapsed
//!   almost nothing: fewer than two pairs per key), prefer the list
//!   flow ([`StageAdapt::prefer_list`]);
//! * **split hot keys** — when the sketch shows one key dominating the
//!   emit stream of a mergeable declared aggregation, spread that key
//!   round-robin across shards in the map phase and merge its partial
//!   holders after the barrier ([`StageAdapt::hot_key`]).
//!
//! Every decision taken is reported in
//! [`PlanReport::adaptation`](crate::api::plan::PlanReport) as an
//! [`AdaptationReport`] and rendered by
//! [`Dataset::explain`](crate::api::plan::Dataset::explain). The preview
//! path consults the *same* store through the *same* pure helpers in
//! this module, so `explain()` never shows a different plan than the one
//! that runs.
//!
//! # Correctness envelope
//!
//! Every adaptation is rewrite-safe by construction: filters commute
//! with each other, shard assignment and hot-key routing only move keys
//! between result shards (canonical digests are order-independent), the
//! list flow is the measured baseline the combining flows are pinned
//! against, and hot-key partial holders are merged with the aggregator's
//! own declared `merge_holders` — only granted for `MERGEABLE`
//! (associative + commutative) aggregators. `OptimizeMode::Off` or
//! [`JobConfig::with_adaptive(false)`](crate::api::config::JobConfig::with_adaptive)
//! bypasses the store entirely, so static behavior stays reachable and
//! adapted ≡ static digest identity is testable
//! (`rust/tests/adaptive_equivalence.rs`).
//!
//! # Caveats
//!
//! Fingerprints of unnamed closures come from `Arc` addresses mapped to
//! first-seen session ordinals (the same identity channel the
//! materialization cache uses): a freed-and-reused allocation can alias
//! two unrelated stages onto one fingerprint. Aliasing degrades
//! *optimality* only — a stale hint may fire or fail to fire — never
//! correctness, since every adaptation preserves results. Measured
//! filter selectivities are *conditional* on the order the filters ran
//! in; the store keeps the latest observation per original stage
//! position, so repeated runs converge but a reorder can shift the
//! measured values once.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Minimum recorded samples before any adaptive decision fires. One
/// completed run is enough: the acceptance contract is that the *second*
/// lowering of an identical prefix may differ.
pub const MIN_SAMPLES: u64 = 1;

/// Minimum observed map-phase emits before shard-count or flow-switch
/// adaptations fire — floors that keep tiny pinned workloads (unit tests,
/// smoke runs) byte-for-byte on the static plan.
pub const MIN_FLOW_EMITS: u64 = 4096;

/// Minimum observed emits before a hot-key split fires.
pub const MIN_SPLIT_EMITS: u64 = 1024;

/// Minimum elements a filter must have seen before its measured
/// selectivity participates in reordering.
pub const MIN_FILTER_SEEN: u64 = 1024;

/// Default staleness TTL for flow and filter statistics, in store ticks.
/// The plan executor advances the store's clock once per completed
/// collect ([`StatsStore::advance_tick`]); an entry not re-recorded for
/// this many ticks is considered obsolete — the workload's distribution
/// may have shifted — and expires lazily at its next lookup. Override
/// with `MR4R_STATS_TTL` (0 disables expiry).
pub const DEFAULT_TTL_TICKS: u64 = 512;

// ---------------------------------------------------------------------
// Observations
// ---------------------------------------------------------------------

/// Key-frequency skew summary of one map phase: the Boyer–Moore majority
/// candidate and its surplus. `hot_support` is a *lower bound* on the
/// candidate's surplus over all other keys combined (`(2f − 1)·n` for a
/// key with frequency `f` of `n` emits), so `hot_support ≥ n/2`
/// guarantees the candidate covers at least 75 % of emits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KeySkew {
    /// FxHash of the dominant key candidate.
    pub hot_hash: u64,
    /// Merged majority surplus (see type docs).
    pub hot_support: u64,
    /// Emits the sketch summarized.
    pub emits: u64,
}

/// One reduce-shaped stage's observed execution, distilled from its
/// [`FlowMetrics`](crate::coordinator::pipeline::FlowMetrics) by the plan
/// executor's epilogue.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowObservation {
    /// Map-phase emits (input pairs of the aggregation).
    pub emits: u64,
    /// Distinct intermediate keys.
    pub keys: u64,
    /// Result pairs produced.
    pub results: u64,
    /// Payload bytes shipped across the barrier (holder footprints for
    /// combining flows — the measured holder-growth signal).
    pub shuffled_bytes: u64,
    /// Whether the combining flow ran.
    pub combine_flow: bool,
    /// Whether the stage ran the *declared* channel (a keyed
    /// [`Aggregator`](crate::api::keyed::Aggregator) stage).
    pub declared: bool,
    /// Whether the stage's aggregator declared `MERGEABLE` — the
    /// precondition for hot-key splitting.
    pub mergeable: bool,
    /// Stage wall time.
    pub total_secs: f64,
    /// Key-frequency sketch, when the flow collected one.
    pub skew: Option<KeySkew>,
}

/// Accumulated per-prefix flow statistics: the latest observation plus a
/// sample count gating confidence.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowStats {
    /// Completed runs recorded for this prefix.
    pub samples: u64,
    /// The most recent observation (last write wins; the sample count
    /// carries the confidence).
    pub last: FlowObservation,
}

/// Accumulated per-filter-prefix selectivity statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct FilterStats {
    /// Completed runs recorded for this filter position.
    pub samples: u64,
    /// Elements the predicate saw on the last run.
    pub seen: u64,
    /// Elements it passed.
    pub passed: u64,
}

impl FilterStats {
    /// Measured pass fraction (1.0 when nothing was seen).
    pub fn selectivity(&self) -> f64 {
        if self.seen == 0 {
            1.0
        } else {
            self.passed as f64 / self.seen as f64
        }
    }
}

/// Shared-counter probe wrapped around an executing filter predicate:
/// the executor counts seen/passed elements and records them into the
/// store under the filter's *original stage position* fingerprint, so a
/// reordered predicate keeps feeding the measurement that identifies it.
#[derive(Debug, Default)]
pub struct FilterProbe {
    pub seen: AtomicU64,
    pub passed: AtomicU64,
}

// ---------------------------------------------------------------------
// Skew sketch
// ---------------------------------------------------------------------

/// Per-chunk Boyer–Moore majority tracker (one per map task, no
/// synchronization): constant space, one branch per emit.
#[derive(Clone, Copy, Debug, Default)]
pub struct MajorityTracker {
    cand: u64,
    weight: u64,
}

impl MajorityTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one emitted key's hash.
    #[inline]
    pub fn hit(&mut self, hash: u64) {
        if self.weight == 0 {
            self.cand = hash;
            self.weight = 1;
        } else if hash == self.cand {
            self.weight += 1;
        } else {
            self.weight -= 1;
        }
    }

    /// The chunk's `(candidate, surplus)` summary.
    pub fn summary(&self) -> (u64, u64) {
        (self.cand, self.weight)
    }
}

/// Mergeable majority sketch: per-chunk `(candidate, surplus)` summaries
/// merge pairwise under a lock, preserving the lower-bound property of
/// the Boyer–Moore surplus. Order of merges does not affect whether a
/// true majority key survives as the candidate.
#[derive(Debug, Default)]
pub struct SkewSketch {
    cand: u64,
    weight: u64,
}

impl SkewSketch {
    /// Merge one chunk summary.
    pub fn absorb(&mut self, cand: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        if self.weight == 0 || cand == self.cand {
            self.cand = cand;
            self.weight += weight;
        } else if self.weight >= weight {
            self.weight -= weight;
        } else {
            self.cand = cand;
            self.weight = weight - self.weight;
        }
    }

    /// The merged sketch over `emits` total emits, if any candidate
    /// survived.
    pub fn finish(&self, emits: u64) -> Option<KeySkew> {
        (self.weight > 0 && emits > 0).then_some(KeySkew {
            hot_hash: self.cand,
            hot_support: self.weight,
            emits,
        })
    }
}

// ---------------------------------------------------------------------
// Hints and decisions
// ---------------------------------------------------------------------

/// Per-stage adaptive execution hints derived from the store at lowering
/// time and carried on the physical plan. Every field is advisory and
/// result-preserving; `None`/`false` means "run the static plan".
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageAdapt {
    /// Collector shard count picked from observed key cardinality
    /// (always smaller than the static default; never below 16).
    pub shard_override: Option<usize>,
    /// Run the keyed list flow even though the declared channel would
    /// grant combining — measured holder growth showed combining
    /// collapsed almost nothing.
    pub prefer_list: bool,
    /// FxHash of a dominant key to spread round-robin across shards in
    /// the map phase (partial holders merged after the barrier). Only
    /// derived for `MERGEABLE` aggregations.
    pub hot_key: Option<u64>,
    /// Sample count behind these hints.
    pub samples: u64,
}

impl StageAdapt {
    /// Whether any hint is active.
    pub fn is_active(&self) -> bool {
        self.shard_override.is_some() || self.prefer_list || self.hot_key.is_some()
    }
}

/// One adaptive decision taken during lowering, named for the report and
/// `explain()`.
#[derive(Clone, Debug, PartialEq)]
pub enum AdaptiveDecision {
    /// Consecutive filter predicates composed in ascending measured
    /// selectivity order instead of recorded order.
    FilterReorder {
        /// Stage index of the first filter in the reordered run.
        first_stage: usize,
        /// Execution order as offsets into the run (recorded order is
        /// `[0, 1, ..]`).
        order: Vec<usize>,
        /// Measured selectivities, in recorded order.
        selectivities: Vec<f64>,
    },
    /// Collector shard count picked from observed cardinality.
    ShardCount {
        stage: usize,
        from: usize,
        to: usize,
        keys: u64,
    },
    /// Declared combining flow demoted to the list flow on measured
    /// holder growth.
    FlowSwitch { stage: usize, emits: u64, keys: u64 },
    /// Dominant key spread across shards and re-merged after the
    /// barrier.
    HotKeySplit {
        stage: usize,
        hot_hash: u64,
        support: u64,
        emits: u64,
    },
}

impl fmt::Display for AdaptiveDecision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdaptiveDecision::FilterReorder {
                first_stage,
                order,
                selectivities,
            } => {
                let sels: Vec<String> =
                    selectivities.iter().map(|s| format!("{s:.3}")).collect();
                write!(
                    f,
                    "filter reorder @ stage {first_stage}: order {order:?}, \
                     measured selectivities [{}]",
                    sels.join(", ")
                )
            }
            AdaptiveDecision::ShardCount {
                stage,
                from,
                to,
                keys,
            } => write!(
                f,
                "shard count @ stage {stage}: {from} -> {to} ({keys} observed key(s))"
            ),
            AdaptiveDecision::FlowSwitch { stage, emits, keys } => write!(
                f,
                "flow switch @ stage {stage}: declared combine -> list \
                 ({emits} emit(s) over {keys} key(s))"
            ),
            AdaptiveDecision::HotKeySplit {
                stage,
                hot_hash,
                support,
                emits,
            } => write!(
                f,
                "hot key split @ stage {stage}: key hash {hot_hash:016x} \
                 (surplus {support} of {emits} emit(s))"
            ),
        }
    }
}

/// The adaptive section of a
/// [`PlanReport`](crate::api::plan::PlanReport): whether the store was
/// consulted, how much evidence backed the hints, and every decision
/// taken.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AdaptationReport {
    /// Whether lowering consulted the feedback store at all (adaptive
    /// config, optimizer not Off).
    pub consulted: bool,
    /// Maximum sample count among the consulted prefix statistics (0 on
    /// a cold store).
    pub samples: u64,
    /// Decisions taken, in stage order.
    pub decisions: Vec<AdaptiveDecision>,
}

// ---------------------------------------------------------------------
// Pure derivation helpers (shared by plan and preview)
// ---------------------------------------------------------------------

/// Derive a stage's execution hints from its accumulated statistics.
/// Pure: `plan` and `plan_preview` both call this with the same store
/// snapshot, which is what pins `explain()` ≡ executed plan.
pub fn derive_stage_adapt(stats: &FlowStats, default_shards: usize) -> Option<StageAdapt> {
    if stats.samples < MIN_SAMPLES {
        return None;
    }
    let obs = &stats.last;
    let mut adapt = StageAdapt {
        samples: stats.samples,
        ..StageAdapt::default()
    };
    if obs.emits >= MIN_FLOW_EMITS && obs.keys > 0 {
        let want = (obs.keys as usize).next_power_of_two().max(16);
        if want < default_shards {
            adapt.shard_override = Some(want);
        }
    }
    if obs.declared && obs.combine_flow && obs.emits >= MIN_FLOW_EMITS {
        // Holder growth contradicting the static choice: fewer than two
        // pairs per key means one holder was allocated, grown, and
        // shipped for nearly every pair — the list flow is cheaper.
        if obs.emits < obs.keys.saturating_mul(2) {
            adapt.prefer_list = true;
        }
    }
    if obs.mergeable && !adapt.prefer_list {
        if let Some(skew) = obs.skew {
            if skew.emits >= MIN_SPLIT_EMITS && skew.hot_support * 2 >= skew.emits {
                adapt.hot_key = Some(skew.hot_hash);
            }
        }
    }
    adapt.is_active().then_some(adapt)
}

/// Choose an execution order for a run of consecutive filters from their
/// measured selectivities: ascending pass fraction, stable on ties.
/// `None` unless every filter in the run has enough evidence
/// ([`MIN_SAMPLES`], [`MIN_FILTER_SEEN`]) *and* the chosen order differs
/// from the recorded one.
pub fn filter_order(stats: &[Option<FilterStats>]) -> Option<Vec<usize>> {
    if stats.len() < 2 {
        return None;
    }
    let mut sels = Vec::with_capacity(stats.len());
    for s in stats {
        let s = (*s)?;
        if s.samples < MIN_SAMPLES || s.seen < MIN_FILTER_SEEN {
            return None;
        }
        sels.push(s.selectivity());
    }
    let mut order: Vec<usize> = (0..sels.len()).collect();
    order.sort_by(|&a, &b| {
        sels[a]
            .partial_cmp(&sels[b])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    if order.iter().enumerate().all(|(i, &j)| i == j) {
        None
    } else {
        Some(order)
    }
}

// ---------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------

/// Flow and filter entries carry the tick they were last recorded at, so
/// lookups can expire measurements the workload stopped refreshing.
/// Prefix costs deliberately do not age: `peak_secs` is a conservative
/// worst-case bound, and the cache's own decay
/// ([`crate::cache::tier::decay`]) already discounts stale recompute
/// value per entry.
#[derive(Debug, Default)]
struct StoreInner {
    flows: HashMap<u64, (FlowStats, u64)>,
    filters: HashMap<u64, (FilterStats, u64)>,
    prefix_costs: HashMap<u64, PrefixCost>,
}

/// Observed materialization cost of one plan prefix — the cost-model
/// export the materialization cache's keep/spill/drop heuristic
/// consults ([`MaterializationCache::attach_cost_feed`]). Recorded by
/// cache cut points whenever a claimed prefix actually computes, so a
/// fingerprint that materialized even once has a measured recompute
/// cost from then on — sharper than the single stopwatch sample an
/// individual cache entry carries.
///
/// [`MaterializationCache::attach_cost_feed`]: crate::cache::MaterializationCache::attach_cost_feed
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixCost {
    /// Materializations observed.
    pub samples: u64,
    /// Most recent observed wall seconds to compute the prefix.
    pub compute_secs: f64,
    /// Largest observed wall seconds across all samples — the
    /// conservative estimate the eviction heuristic uses.
    pub peak_secs: f64,
    /// Most recent observed output bytes (cache payload).
    pub output_bytes: u64,
}

/// The per-session optimizer feedback store, owned by
/// [`Runtime`](crate::api::Runtime) and shared by every plan the session
/// lowers. Keys are structural prefix fingerprints
/// ([`crate::cache::fingerprint::prefix_fingerprints`]); flow statistics
/// are keyed by the reduce-shaped stage's prefix, filter statistics by
/// the filter stage's *original* (recorded) position prefix.
#[derive(Debug)]
pub struct StatsStore {
    inner: Mutex<StoreInner>,
    records: AtomicU64,
    consult_hits: AtomicU64,
    expired: AtomicU64,
    /// Staleness clock: one tick per completed plan collect.
    tick: AtomicU64,
    /// Ticks an un-refreshed flow/filter entry stays consultable
    /// (0 = never expire).
    ttl: u64,
}

impl Default for StatsStore {
    fn default() -> Self {
        let ttl = std::env::var("MR4R_STATS_TTL")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_TTL_TICKS);
        StatsStore::with_ttl(ttl)
    }
}

impl StatsStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// A store whose flow/filter entries expire after going `ttl` ticks
    /// without a fresh recording (0 disables expiry).
    pub fn with_ttl(ttl: u64) -> Self {
        StatsStore {
            inner: Mutex::new(StoreInner::default()),
            records: AtomicU64::new(0),
            consult_hits: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            ttl,
        }
    }

    /// Advance the staleness clock one tick. The plan executor calls
    /// this once per collect epilogue, so entry age is measured in
    /// completed plans — the same unit the materialization cache's decay
    /// uses — not wall time.
    pub fn advance_tick(&self) {
        self.tick.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one reduce-shaped stage's observed execution.
    pub fn record_flow(&self, fp: u64, obs: FlowObservation) {
        let now = self.tick.load(Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.flows.entry(fp).or_default();
        entry.0.samples += 1;
        entry.0.last = obs;
        entry.1 = now;
        drop(inner);
        self.records.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one filter position's observed selectivity. Zero-seen
    /// observations (the filter never executed — e.g. its prefix was
    /// served from the materialization cache) are discarded.
    pub fn record_filter(&self, fp: u64, seen: u64, passed: u64) {
        if seen == 0 {
            return;
        }
        let now = self.tick.load(Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.filters.entry(fp).or_default();
        entry.0.samples += 1;
        entry.0.seen = seen;
        entry.0.passed = passed;
        entry.1 = now;
        drop(inner);
        self.records.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one observed prefix materialization: the wall seconds a
    /// cache cut point spent computing its prefix and the bytes it
    /// produced.
    pub fn record_prefix_cost(&self, fp: u64, compute_secs: f64, output_bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        let entry = inner.prefix_costs.entry(fp).or_default();
        entry.samples += 1;
        entry.compute_secs = compute_secs;
        entry.peak_secs = entry.peak_secs.max(compute_secs);
        entry.output_bytes = output_bytes;
        drop(inner);
        self.records.fetch_add(1, Ordering::Relaxed);
    }

    /// Look up a prefix's observed materialization cost. Unlike
    /// [`StatsStore::flow`]/[`StatsStore::filter`], a hit does *not*
    /// count as a consult: this is read internally by every eviction
    /// pass, and counting those would drown the "second lowering
    /// consulted the store" observable the adaptive tests pin.
    pub fn prefix_cost(&self, fp: u64) -> Option<PrefixCost> {
        self.inner.lock().unwrap().prefix_costs.get(&fp).copied()
    }

    /// Look up a prefix's flow statistics (a hit counts as a consult).
    /// An entry past the staleness TTL expires here instead of hitting:
    /// acting on measurements from a distribution the workload left
    /// behind is worse than running the static plan.
    pub fn flow(&self, fp: u64) -> Option<FlowStats> {
        let now = self.tick.load(Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let hit = match inner.flows.get(&fp) {
            Some(&(_, stamp)) if self.ttl > 0 && now.saturating_sub(stamp) > self.ttl => {
                inner.flows.remove(&fp);
                self.expired.fetch_add(1, Ordering::Relaxed);
                None
            }
            Some(&(stats, _)) => Some(stats),
            None => None,
        };
        drop(inner);
        if hit.is_some() {
            self.consult_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Look up a filter position's statistics (a hit counts as a
    /// consult). Stale entries expire exactly like [`StatsStore::flow`].
    pub fn filter(&self, fp: u64) -> Option<FilterStats> {
        let now = self.tick.load(Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let hit = match inner.filters.get(&fp) {
            Some(&(_, stamp)) if self.ttl > 0 && now.saturating_sub(stamp) > self.ttl => {
                inner.filters.remove(&fp);
                self.expired.fetch_add(1, Ordering::Relaxed);
                None
            }
            Some(&(stats, _)) => Some(stats),
            None => None,
        };
        drop(inner);
        if hit.is_some() {
            self.consult_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Observations recorded so far.
    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    /// Lookups that found prior statistics — the "second lowering
    /// consulted the store" observable.
    pub fn consults(&self) -> u64 {
        self.consult_hits.load(Ordering::Relaxed)
    }

    /// Flow/filter entries that aged past the TTL and were dropped at
    /// lookup instead of feeding a hint.
    pub fn expired(&self) -> u64 {
        self.expired.load(Ordering::Relaxed)
    }

    /// Distinct prefixes with recorded statistics.
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.flows.len() + inner.filters.len() + inner.prefix_costs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every recorded statistic (counters included).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.flows.clear();
        inner.filters.clear();
        inner.prefix_costs.clear();
        drop(inner);
        self.records.store(0, Ordering::Relaxed);
        self.consult_hits.store(0, Ordering::Relaxed);
        self.expired.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn big_flow() -> FlowObservation {
        FlowObservation {
            emits: 100_000,
            keys: 5,
            results: 5,
            shuffled_bytes: 80,
            combine_flow: true,
            declared: true,
            mergeable: true,
            total_secs: 0.01,
            skew: None,
        }
    }

    #[test]
    fn store_round_trips_and_counts() {
        let s = StatsStore::new();
        assert!(s.flow(1).is_none());
        assert_eq!(s.consults(), 0, "misses are not consults");
        s.record_flow(1, big_flow());
        s.record_flow(1, big_flow());
        let got = s.flow(1).unwrap();
        assert_eq!(got.samples, 2);
        assert_eq!(got.last.emits, 100_000);
        assert_eq!(s.records(), 2);
        assert_eq!(s.consults(), 1);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.consults(), 0);
    }

    #[test]
    fn prefix_costs_track_peak_without_counting_consults() {
        let s = StatsStore::new();
        assert!(s.prefix_cost(9).is_none());
        s.record_prefix_cost(9, 0.5, 1000);
        s.record_prefix_cost(9, 0.1, 800);
        let pc = s.prefix_cost(9).unwrap();
        assert_eq!(pc.samples, 2);
        assert_eq!(pc.compute_secs, 0.1, "latest sample");
        assert_eq!(pc.peak_secs, 0.5, "worst observed materialization");
        assert_eq!(pc.output_bytes, 800);
        assert_eq!(s.records(), 2);
        assert_eq!(s.len(), 1);
        // Eviction passes read costs constantly; they must not drown
        // the lowering-consult observable.
        assert_eq!(s.consults(), 0);
        s.clear();
        assert!(s.prefix_cost(9).is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn stale_filter_reorder_hint_expires_after_the_ttl() {
        // A workload phase with one expensive high-pass filter ahead of a
        // cheap low-pass one: the measurements justify a reorder.
        let s = StatsStore::with_ttl(4);
        s.record_filter(1, 10_000, 9_000);
        s.record_filter(2, 10_000, 500);
        assert_eq!(
            filter_order(&[s.filter(1), s.filter(2)]),
            Some(vec![1, 0]),
            "fresh selectivities drive the reorder hint"
        );
        // The distribution shifts and those filters never run again
        // (e.g. their prefix is now served by the materialization
        // cache): after TTL+1 collect epilogues the evidence is stale.
        for _ in 0..5 {
            s.advance_tick();
        }
        assert!(s.filter(1).is_none(), "stale selectivity must expire");
        assert!(s.filter(2).is_none());
        assert_eq!(s.expired(), 2);
        assert_eq!(
            filter_order(&[s.filter(1), s.filter(2)]),
            None,
            "the obsolete reorder hint dies with its evidence"
        );
    }

    #[test]
    fn flow_statistics_expire_and_restart_cold() {
        let s = StatsStore::with_ttl(4);
        s.record_flow(3, big_flow());
        s.advance_tick();
        assert_eq!(s.flow(3).unwrap().samples, 1, "within the TTL: consultable");
        for _ in 0..5 {
            s.advance_tick();
        }
        assert!(s.flow(3).is_none());
        assert_eq!(s.expired(), 1);
        // A fresh recording restarts the entry's clock and confidence.
        s.record_flow(3, big_flow());
        assert_eq!(s.flow(3).unwrap().samples, 1, "expired entries restart cold");
        // TTL 0 disables expiry entirely.
        let forever = StatsStore::with_ttl(0);
        forever.record_flow(4, big_flow());
        for _ in 0..100 {
            forever.advance_tick();
        }
        assert!(forever.flow(4).is_some());
        assert_eq!(forever.expired(), 0);
    }

    #[test]
    fn zero_seen_filter_observations_are_discarded() {
        let s = StatsStore::new();
        s.record_filter(7, 0, 0);
        assert!(s.filter(7).is_none());
        s.record_filter(7, 2000, 100);
        assert_eq!(s.filter(7).unwrap().passed, 100);
    }

    #[test]
    fn shard_override_shrinks_to_observed_cardinality() {
        let stats = FlowStats {
            samples: 1,
            last: big_flow(),
        };
        let adapt = derive_stage_adapt(&stats, 128).unwrap();
        assert_eq!(adapt.shard_override, Some(16), "clamped to >= 16");
        // Default already small: no override.
        let none = derive_stage_adapt(&stats, 16);
        assert!(none.is_none_or(|a| a.shard_override.is_none()));
    }

    #[test]
    fn flow_switch_requires_holder_growth_evidence() {
        let mut obs = big_flow();
        obs.keys = 99_000; // < 2 pairs per key: combining collapsed nothing
        let adapt = derive_stage_adapt(&FlowStats { samples: 1, last: obs }, 16).unwrap();
        assert!(adapt.prefer_list);
        // Plenty of collapse: stays combining.
        let adapt = derive_stage_adapt(
            &FlowStats {
                samples: 1,
                last: big_flow(),
            },
            16,
        );
        assert!(adapt.is_none_or(|a| !a.prefer_list));
    }

    #[test]
    fn hot_key_split_requires_majority_surplus_and_mergeable() {
        let mut obs = big_flow();
        obs.skew = Some(KeySkew {
            hot_hash: 0xABCD,
            hot_support: 80_000,
            emits: 100_000,
        });
        let adapt = derive_stage_adapt(&FlowStats { samples: 1, last: obs }, 16).unwrap();
        assert_eq!(adapt.hot_key, Some(0xABCD));
        // Below the surplus threshold: no split.
        obs.skew = Some(KeySkew {
            hot_hash: 0xABCD,
            hot_support: 10_000,
            emits: 100_000,
        });
        let adapt = derive_stage_adapt(&FlowStats { samples: 1, last: obs }, 16);
        assert!(adapt.is_none_or(|a| a.hot_key.is_none()));
        // Not mergeable: no split even with a dominant key.
        obs.skew = Some(KeySkew {
            hot_hash: 0xABCD,
            hot_support: 80_000,
            emits: 100_000,
        });
        obs.mergeable = false;
        let adapt = derive_stage_adapt(&FlowStats { samples: 1, last: obs }, 16);
        assert!(adapt.is_none_or(|a| a.hot_key.is_none()));
    }

    #[test]
    fn tiny_workloads_never_adapt() {
        let obs = FlowObservation {
            emits: 10,
            keys: 6,
            declared: true,
            combine_flow: true,
            mergeable: true,
            skew: Some(KeySkew {
                hot_hash: 1,
                hot_support: 9,
                emits: 10,
            }),
            ..FlowObservation::default()
        };
        assert!(derive_stage_adapt(&FlowStats { samples: 5, last: obs }, 128).is_none());
    }

    #[test]
    fn filter_order_sorts_ascending_and_gates_on_evidence() {
        let hi = FilterStats {
            samples: 1,
            seen: 10_000,
            passed: 9_000,
        };
        let lo = FilterStats {
            samples: 1,
            seen: 10_000,
            passed: 500,
        };
        assert_eq!(filter_order(&[Some(hi), Some(lo)]), Some(vec![1, 0]));
        // Already cheapest-first: no decision.
        assert_eq!(filter_order(&[Some(lo), Some(hi)]), None);
        // Missing evidence on one filter: no decision.
        assert_eq!(filter_order(&[Some(hi), None]), None);
        // Under the seen floor: no decision.
        let tiny = FilterStats {
            samples: 1,
            seen: 10,
            passed: 1,
        };
        assert_eq!(filter_order(&[Some(hi), Some(tiny)]), None);
        // Ties are stable.
        assert_eq!(filter_order(&[Some(hi), Some(hi)]), None);
    }

    #[test]
    fn majority_sketch_finds_a_dominant_key() {
        // 90 % of emits are key 7: the merged surplus must clear the
        // split threshold regardless of chunking.
        let hashes: Vec<u64> = (0..10_000u64).map(|i| if i % 10 == 0 { i } else { 7 }).collect();
        let mut sketch = SkewSketch::default();
        for chunk in hashes.chunks(997) {
            let mut t = MajorityTracker::new();
            for h in chunk {
                t.hit(*h);
            }
            let (c, w) = t.summary();
            sketch.absorb(c, w);
        }
        let skew = sketch.finish(hashes.len() as u64).unwrap();
        assert_eq!(skew.hot_hash, 7);
        assert!(
            skew.hot_support * 2 >= skew.emits,
            "surplus {} of {}",
            skew.hot_support,
            skew.emits
        );
        // Near-uniform keys: no candidate clears the threshold.
        let mut sketch = SkewSketch::default();
        for chunk in (0..10_000u64).collect::<Vec<_>>().chunks(997) {
            let mut t = MajorityTracker::new();
            for h in chunk {
                t.hit(*h);
            }
            let (c, w) = t.summary();
            sketch.absorb(c, w);
        }
        let ok = match sketch.finish(10_000) {
            None => true,
            Some(s) => s.hot_support * 2 < s.emits,
        };
        assert!(ok, "uniform stream must not elect a hot key");
    }

    #[test]
    fn decisions_render_for_explain() {
        let d = AdaptiveDecision::ShardCount {
            stage: 2,
            from: 128,
            to: 16,
            keys: 5,
        };
        assert_eq!(
            d.to_string(),
            "shard count @ stage 2: 128 -> 16 (5 observed key(s))"
        );
        assert!(AdaptiveDecision::HotKeySplit {
            stage: 1,
            hot_hash: 0xABCD,
            support: 10,
            emits: 20,
        }
        .to_string()
        .contains("000000000000abcd"));
        assert!(AdaptiveDecision::FilterReorder {
            first_stage: 1,
            order: vec![1, 0],
            selectivities: vec![0.9, 0.05],
        }
        .to_string()
        .contains("[0.900, 0.050]"));
        assert!(AdaptiveDecision::FlowSwitch {
            stage: 3,
            emits: 10,
            keys: 9,
        }
        .to_string()
        .contains("declared combine -> list"));
    }
}
