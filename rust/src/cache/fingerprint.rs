//! Structural fingerprints of plan-stage prefixes.
//!
//! A fingerprint identifies "the computation a plan performs up to stage
//! `i`" well enough that two plans with equal prefix fingerprints may
//! share one materialized result (see [`crate::cache`]). It is computed
//! by the planner ([`crate::coordinator::planner::lower`]) from the
//! recorded [`StageInfo`] list — never from closure bodies, which the
//! framework cannot inspect (the same blind spot the paper's agent works
//! around with bytecode analysis; here the plan structure *is* the
//! inspectable artifact).
//!
//! A prefix fingerprint covers, in order, for every stage up to the cut:
//!
//! * the stage **kind** (`Source`/`Map`/`MapReduce`/`Cache`/…);
//! * the stage **name** (reducer class name for reduce stages);
//! * the **optimizer mode** the stage was recorded under — an
//!   `OptimizeMode::Off` run never reads an `Auto` run's entries;
//! * the stage's **identity token** ([`StageToken`]): either a
//!   caller-declared stable value (`Dataset::tag`), or a raw address
//!   (source buffer, mapper/reducer `Arc`s) that the planner maps to a
//!   **first-seen session ordinal** while lowering — only for plans that
//!   actually mark a cache cut, so plans that never cache register
//!   nothing.
//!
//! Ordinals — not raw addresses — are what get hashed, so fingerprints
//! are **stable across sessions**: an application that opens a new
//! session and registers its sources and reducer classes in the same
//! order reproduces the same fingerprints, while registering them in a
//! different order changes every downstream fingerprint (the
//! registration-order sensitivity that keeps distinct closures from
//! colliding). Address identities are valid only while their referent is
//! alive (see the aliasing note on [`Dataset::cache`]); stages whose
//! identity the framework cannot observe (anonymous `map`/`filter`
//! closures) hash by kind + name + mode + position only.
//!
//! Since the adaptive re-optimization work, prefix fingerprints also key
//! the session [`StatsStore`](crate::stats::StatsStore): plans that never
//! cache still compute them so that measured runtime behavior can be
//! recorded per prefix and consulted at the next lowering. For such
//! non-caching plans an address-reuse collision (an `Arc` freed and a new
//! one allocated at the same address) can at worst alias two prefixes'
//! *statistics* — degrading a lowering hint, never correctness, because
//! every adaptive rewrite is digest-preserving by construction.
//!
//! [`Dataset::cache`]: crate::api::plan::Dataset::cache
//! [`StageInfo`]: crate::api::plan::StageInfo
//! [`StageToken`]: crate::api::plan::StageToken

use std::hash::Hasher;

use crate::api::config::OptimizeMode;
use crate::api::plan::{StageInfo, StageKind, StageToken};
use crate::util::hash::FxHasher;

use super::MaterializationCache;

/// A structural prefix fingerprint — the materialization-cache key.
/// Ordered so eviction tie-breaks are deterministic across runs.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Fingerprint(pub u64);

impl std::fmt::Debug for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Fingerprint({self})")
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

fn kind_code(k: StageKind) -> u64 {
    match k {
        StageKind::Source => 1,
        StageKind::Map => 2,
        StageKind::Filter => 3,
        StageKind::FlatMap => 4,
        StageKind::MapReduce => 5,
        StageKind::KeyedAggregate => 6,
        StageKind::CoGroup => 7,
        StageKind::Cache => 8,
    }
}

fn mode_code(m: OptimizeMode) -> u64 {
    match m {
        OptimizeMode::Auto => 1,
        OptimizeMode::Off => 2,
        OptimizeMode::GenericOnly => 3,
    }
}

/// Cumulative structural hash after each stage: `out[i]` fingerprints the
/// prefix `stages[0..=i]`. One pass, reused by the planner for every cut
/// point in the plan. `registry` supplies the address → first-seen
/// ordinal mapping ([`MaterializationCache::identity_ordinal`]).
pub fn prefix_fingerprints(stages: &[StageInfo], registry: &MaterializationCache) -> Vec<u64> {
    let mut h = FxHasher::default();
    let mut out = Vec::with_capacity(stages.len());
    for (i, s) in stages.iter().enumerate() {
        h.write_u64(i as u64);
        h.write_u64(kind_code(s.kind));
        h.write(s.name.as_bytes());
        h.write_u64(mode_code(s.optimize));
        match s.token {
            Some(StageToken::Stable(t)) => {
                h.write_u64(1);
                h.write_u64(t);
            }
            Some(StageToken::Address(raw)) => {
                h.write_u64(2);
                h.write_u64(registry.identity_ordinal(raw));
            }
            None => h.write_u64(0),
        }
        out.push(h.finish());
    }
    out
}

/// Whether a plan's prefixes can be cached at all: the plan must be
/// rooted at a [`StageKind::Source`] whose identity the framework
/// observed (slice/vec sources and plan/job outputs provide one;
/// streaming generators do not, and co-group-rooted plans own no source).
pub fn cacheable(stages: &[StageInfo]) -> bool {
    stages
        .first()
        .is_some_and(|s| s.kind == StageKind::Source && s.token.is_some())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(
        kind: StageKind,
        name: &str,
        mode: OptimizeMode,
        token: Option<StageToken>,
    ) -> StageInfo {
        StageInfo {
            kind,
            name: name.into(),
            optimize: mode,
            token,
        }
    }

    fn sample() -> Vec<StageInfo> {
        vec![
            info(
                StageKind::Source,
                "source",
                OptimizeMode::Auto,
                Some(StageToken::Stable(11)),
            ),
            info(
                StageKind::MapReduce,
                "wc",
                OptimizeMode::Auto,
                Some(StageToken::Address(0xBEEF)),
            ),
            info(StageKind::Cache, "cache", OptimizeMode::Auto, None),
        ]
    }

    #[test]
    fn identical_prefixes_fingerprint_equal() {
        let reg = MaterializationCache::new();
        assert_eq!(
            prefix_fingerprints(&sample(), &reg),
            prefix_fingerprints(&sample(), &reg)
        );
    }

    #[test]
    fn sensitive_to_every_component() {
        let reg = MaterializationCache::new();
        let base = prefix_fingerprints(&sample(), &reg);
        // Stage kind.
        let mut s = sample();
        s[1].kind = StageKind::KeyedAggregate;
        assert_ne!(prefix_fingerprints(&s, &reg)[2], base[2]);
        // Stage name.
        let mut s = sample();
        s[1].name = "hist".into();
        assert_ne!(prefix_fingerprints(&s, &reg)[2], base[2]);
        // Optimizer mode.
        let mut s = sample();
        s[1].optimize = OptimizeMode::Off;
        assert_ne!(prefix_fingerprints(&s, &reg)[2], base[2]);
        // Closure identity (distinct addresses → distinct ordinals).
        let mut s = sample();
        s[1].token = Some(StageToken::Address(0xF00D));
        assert_ne!(prefix_fingerprints(&s, &reg)[2], base[2]);
        // Source identity.
        let mut s = sample();
        s[0].token = Some(StageToken::Stable(12));
        assert_ne!(prefix_fingerprints(&s, &reg)[2], base[2]);
        // Anonymous vs identified.
        let mut s = sample();
        s[1].token = None;
        assert_ne!(prefix_fingerprints(&s, &reg)[2], base[2]);
    }

    #[test]
    fn address_tokens_hash_by_registration_order() {
        // Two registries that see the same addresses in the same order
        // agree; a registry that saw them in the other order does not —
        // the "stable across sessions, sensitive to registration order"
        // contract.
        let stages = sample();
        let reg_a = MaterializationCache::new();
        let fps_a = prefix_fingerprints(&stages, &reg_a);
        let reg_b = MaterializationCache::new();
        assert_eq!(prefix_fingerprints(&stages, &reg_b), fps_a);
        let reg_c = MaterializationCache::new();
        reg_c.identity_ordinal(0x5EED); // someone else registered first
        assert_ne!(prefix_fingerprints(&stages, &reg_c), fps_a);
    }

    #[test]
    fn fingerprints_are_cumulative() {
        let reg = MaterializationCache::new();
        let fps = prefix_fingerprints(&sample(), &reg);
        assert_eq!(fps.len(), 3);
        assert_ne!(fps[0], fps[1]);
        assert_ne!(fps[1], fps[2]);
        // A longer plan's prefixes match the shorter plan's stage for stage.
        let mut longer = sample();
        longer.push(info(
            StageKind::MapReduce,
            "tail",
            OptimizeMode::Auto,
            Some(StageToken::Address(0xCAFE)),
        ));
        assert_eq!(prefix_fingerprints(&longer, &reg)[..3], fps[..]);
    }

    #[test]
    fn cacheable_requires_identified_source_root() {
        assert!(cacheable(&sample()));
        let mut anon = sample();
        anon[0].token = None; // stream source: no identity
        assert!(!cacheable(&anon));
        let cogroup = vec![info(StageKind::CoGroup, "co_group", OptimizeMode::Auto, None)];
        assert!(!cacheable(&cogroup));
        assert!(!cacheable(&[]));
    }
}
