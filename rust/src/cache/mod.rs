//! The plan-aware materialization cache — cross-plan reuse of computed
//! subplan results, with pressure-aware eviction.
//!
//! The lazy [`Dataset`](crate::api::plan::Dataset) layer gave the
//! framework something the paper's per-class agent never had: whole plans
//! are structurally inspectable *before* they run. This module spends
//! that semantic information on a second framework-level optimization
//! (in the spirit of MANIMAL's pre-execution plan analysis and the reuse
//! family in Rao & Wang's semantics-aware-optimization taxonomy):
//! **identical plan prefixes are computed once**. A k-means driver that
//! re-derives its point dataset every Lloyd iteration, or two concurrent
//! tenants collecting the same source + stage chain, share one
//! materialization instead of re-running — and re-allocating — the same
//! subplan.
//!
//! Moving parts:
//!
//! * [`fingerprint`] — structural prefix fingerprints, computed by the
//!   planner during lowering (source identity + stage kinds/names +
//!   closure registration order + [`OptimizeMode`]).
//! * [`MaterializationCache`] — one per [`Runtime`] session: finished
//!   shard outputs keyed by fingerprint. Entries are charged to a
//!   dedicated scoped [`SimHeap`] cohort (`"cache.entry"`), so cached
//!   bytes are *live simulated heap* — the cache competes for the same
//!   memory the paper's GC study measures, which is exactly why eviction
//!   is pressure-aware.
//! * **In-flight deduplication** — the first plan to miss a fingerprint
//!   claims the entry and computes; concurrent plans racing on the same
//!   uncached prefix block on the entry and reuse the one result
//!   ([`CacheStats::shared_in_flight`] counts them). A claimant that
//!   panics aborts its claim on unwind, so waiters recover and compute.
//! * **Pressure-aware eviction** — when the producing job's simulated
//!   heap occupancy crosses [`CacheConfig::watermark`] (or total cached
//!   bytes exceed [`CacheConfig::max_bytes`]), least-recently-used
//!   entries go first, cheapest-to-recompute first among equals, and
//!   their cohorts are released back to the heap.
//!
//! The cache is populated and read **only at explicit
//! [`Dataset::cache`](crate::api::plan::Dataset::cache) cut points**: a
//! plan that never marks a cut never probes the cache, so eager jobs and
//! un-annotated plans are byte-for-byte unaffected. Read-through is
//! automatic *across* plans: any plan marking a cut whose prefix
//! fingerprint matches a stored entry reuses it, whichever tenant stored
//! it.
//!
//! [`OptimizeMode`]: crate::api::config::OptimizeMode
//! [`Runtime`]: crate::api::Runtime
//! [`SimHeap`]: crate::memsim::SimHeap

pub mod fingerprint;

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};

use crate::api::config::CacheConfig;
use crate::govern::TenantHandle;
use crate::memsim::{CohortId, SimHeap};

pub use fingerprint::Fingerprint;

/// Per-element bookkeeping overhead charged for a cached element beside
/// its [`HeapSized`](crate::api::traits::HeapSized) payload (the shard
/// slot, mirroring the collector's list-slot accounting).
pub const ENTRY_SLOT_BYTES: u64 = 16;

/// Session-cumulative cache statistics (the numbers the acceptance
/// criteria and the harness report read).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Cut-point reads served from a ready entry without waiting.
    pub hits: u64,
    /// Cut-point reads that found no entry and computed the prefix.
    pub misses: u64,
    /// Cut-point reads that blocked on another plan's in-flight
    /// computation and shared its result (the dedup observable).
    pub shared_in_flight: u64,
    /// Ready entries whose stored type did not match the reading cut's
    /// element type (a fingerprint collision across types — the reader
    /// recomputed without touching the entry).
    pub type_conflicts: u64,
    /// Entries evicted under pressure (cumulative).
    pub evictions: u64,
    /// Append-delta merges: a cut point found a ready entry whose
    /// append-aware source (see
    /// [`InputSource::append_len`](crate::api::InputSource::append_len))
    /// had grown, recomputed only the appended tail, and merged it into
    /// the entry — a prefix hit *plus* a delta, never a full recompute.
    pub delta_merges: u64,
    /// Elements appended into existing entries via delta merges.
    pub delta_items: u64,
    /// Bytes currently cached (live `cache.entry` cohort bytes).
    pub bytes_cached: u64,
    /// Ready entries currently stored.
    pub entries: usize,
}

/// What one plan did to the cache (the per-plan slice of [`CacheStats`],
/// reported in [`PlanReport::cache`](crate::api::plan::PlanReport) and on
/// the consuming stage's
/// [`FlowMetrics::cache`](crate::coordinator::pipeline::FlowMetrics)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheActivity {
    pub hits: u64,
    pub misses: u64,
    pub shared_in_flight: u64,
    /// Evictions this plan's inserts triggered.
    pub evictions: u64,
    /// Bytes this plan inserted into the cache.
    pub bytes_inserted: u64,
}

impl CacheActivity {
    pub(crate) fn add(&mut self, other: &CacheActivity) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.shared_in_flight += other.shared_in_flight;
        self.evictions += other.evictions;
        self.bytes_inserted += other.bytes_inserted;
    }
}

/// Type-erased cached shard outputs (`Arc<Vec<Vec<T>>>` behind `Any`; the
/// cut point downcasts back to its concrete element type).
pub(crate) type Stored = Arc<dyn Any + Send + Sync>;

enum EntryState {
    /// A plan claimed this fingerprint and is computing the prefix.
    InFlight,
    Ready(Stored),
}

struct Entry {
    state: EntryState,
    bytes: u64,
    /// Wall seconds the producing plan spent computing the prefix — the
    /// recompute cost the eviction policy protects.
    recompute_secs: f64,
    /// LRU clock value of the last read/insert.
    last_used: u64,
    /// Source items this entry's value covers, when the producing cut's
    /// source was append-aware — the high-water mark delta merges compare
    /// against. `None` for fixed sources (no delta maintenance).
    seen: Option<u64>,
    /// The simulated-heap cohorts holding this entry's bytes live (the
    /// original insert plus one per delta merge; all released on
    /// eviction/removal).
    cohorts: Vec<(Arc<SimHeap>, CohortId)>,
    /// The tenant whose plan produced this entry, when it ran governed:
    /// the entry's bytes (including later delta merges) count against
    /// that tenant's live-cache budget until release (see
    /// [`crate::govern`]).
    tenant: Option<Arc<TenantHandle>>,
}

struct CacheInner {
    entries: HashMap<Fingerprint, Entry>,
    /// Raw identity → first-seen registration ordinal (what fingerprints
    /// hash, making them session-order-stable rather than address-bound).
    identity: HashMap<u64, u64>,
    next_ordinal: u64,
    stats: CacheStats,
    /// LRU clock.
    tick: u64,
}

/// Outcome of [`MaterializationCache::begin`].
pub(crate) enum Begin<'c> {
    /// A ready entry was found (`waited` → only after blocking on another
    /// plan's in-flight computation). `seen` is the entry's append
    /// high-water mark, when its source was append-aware — the reader
    /// compares it against the source's current length to decide whether
    /// a delta merge is due.
    Ready {
        value: Stored,
        waited: bool,
        seen: Option<u64>,
    },
    /// This caller claimed the fingerprint: compute the prefix, then
    /// [`MaterializationCache::complete`] the ticket (dropping it without
    /// completing — e.g. on unwind — aborts the claim and wakes waiters).
    Claimed(Ticket<'c>),
}

/// An in-flight claim on a fingerprint (see [`Begin::Claimed`]).
pub(crate) struct Ticket<'c> {
    cache: &'c MaterializationCache,
    fp: Fingerprint,
    done: bool,
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        if !self.done {
            // The claimant unwound before completing: withdraw the
            // in-flight entry so waiters recover and compute themselves.
            let mut inner = self.cache.inner.lock().unwrap();
            if matches!(
                inner.entries.get(&self.fp),
                Some(Entry {
                    state: EntryState::InFlight,
                    ..
                })
            ) {
                inner.entries.remove(&self.fp);
            }
            drop(inner);
            self.cache.ready.notify_all();
        }
    }
}

/// The session-level materialization cache (owned by
/// [`Runtime`](crate::api::Runtime), shared by every plan on the
/// session). See the [module docs](self).
pub struct MaterializationCache {
    inner: Mutex<CacheInner>,
    ready: Condvar,
}

impl Default for MaterializationCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MaterializationCache {
    pub fn new() -> Self {
        MaterializationCache {
            inner: Mutex::new(CacheInner {
                entries: HashMap::new(),
                identity: HashMap::new(),
                next_ordinal: 0,
                stats: CacheStats::default(),
                tick: 0,
            }),
            ready: Condvar::new(),
        }
    }

    /// Map a raw identity (a source address, a closure `Arc` pointer) to
    /// its session registration ordinal, assigned in first-seen order.
    /// Fingerprints hash ordinals, never raw addresses — see
    /// [`fingerprint`].
    pub fn identity_ordinal(&self, raw: u64) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&ord) = inner.identity.get(&raw) {
            return ord;
        }
        let ord = inner.next_ordinal;
        inner.next_ordinal += 1;
        inner.identity.insert(raw, ord);
        ord
    }

    /// Snapshot the session-cumulative statistics.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Whether a ready entry exists for `fp` (tests and diagnostics).
    pub fn contains(&self, fp: Fingerprint) -> bool {
        matches!(
            self.inner.lock().unwrap().entries.get(&fp),
            Some(Entry {
                state: EntryState::Ready(_),
                ..
            })
        )
    }

    /// Resolve a cut point: return the ready entry, wait out another
    /// plan's in-flight computation, or claim the fingerprint for this
    /// caller to compute. Misses are counted here; successful reads are
    /// counted by the caller via [`MaterializationCache::record_read`]
    /// *after* its typed downcast succeeds (a type conflict is not a
    /// served read).
    pub(crate) fn begin(&self, fp: Fingerprint) -> Begin<'_> {
        let mut inner = self.inner.lock().unwrap();
        let mut waited = false;
        loop {
            let ready = match inner.entries.get(&fp) {
                Some(Entry {
                    state: EntryState::Ready(v),
                    seen,
                    ..
                }) => Some((Arc::clone(v), *seen)),
                Some(Entry {
                    state: EntryState::InFlight,
                    ..
                }) => {
                    waited = true;
                    inner = self.ready.wait(inner).unwrap();
                    continue;
                }
                None => None,
            };
            return match ready {
                Some((value, seen)) => {
                    inner.tick += 1;
                    let tick = inner.tick;
                    if let Some(e) = inner.entries.get_mut(&fp) {
                        e.last_used = tick;
                    }
                    Begin::Ready {
                        value,
                        waited,
                        seen,
                    }
                }
                None => {
                    inner.entries.insert(
                        fp,
                        Entry {
                            state: EntryState::InFlight,
                            bytes: 0,
                            recompute_secs: 0.0,
                            last_used: 0,
                            seen: None,
                            cohorts: Vec::new(),
                            tenant: None,
                        },
                    );
                    inner.stats.misses += 1;
                    Begin::Claimed(Ticket {
                        cache: self,
                        fp,
                        done: false,
                    })
                }
            };
        }
    }

    /// Count one successfully served read (`waited` → it shared another
    /// plan's in-flight computation instead of finding the entry ready).
    pub(crate) fn record_read(&self, waited: bool) {
        let mut inner = self.inner.lock().unwrap();
        if waited {
            inner.stats.shared_in_flight += 1;
        } else {
            inner.stats.hits += 1;
        }
    }

    /// Count one cross-type fingerprint collision (the reader recomputed
    /// without being served).
    pub(crate) fn record_type_conflict(&self) {
        self.inner.lock().unwrap().stats.type_conflicts += 1;
    }

    /// Publish a claimed entry: charge its bytes to a fresh scoped cohort
    /// on the producing job's heap (cached bytes are live simulated
    /// heap), store the value, run pressure-aware eviction, and wake any
    /// plans waiting on the fingerprint. `seen` is the append high-water
    /// mark for append-aware sources (`None` for fixed sources). When the
    /// producing plan ran governed, `tenant` owns the entry's bytes: they
    /// are charged to its live-cache counter now and credited back on
    /// release. Returns the number of entries evicted by this insert.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn complete(
        &self,
        mut ticket: Ticket<'_>,
        value: Stored,
        bytes: u64,
        items: u64,
        recompute_secs: f64,
        seen: Option<u64>,
        heap: &Arc<SimHeap>,
        cfg: &CacheConfig,
        tenant: Option<Arc<TenantHandle>>,
    ) -> u64 {
        ticket.done = true;
        let fp = ticket.fp;
        // Account before taking the cache lock: the allocation may run a
        // simulated GC, which takes the heap lock (never the cache's).
        let cohort = heap.scoped_cohort("cache.entry");
        let mut alloc = heap.thread_alloc();
        alloc.alloc_n(cohort, bytes, items.max(1));
        alloc.flush();
        drop(alloc);

        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner
            .entries
            .get_mut(&fp)
            .expect("claimed entry present until completed or aborted");
        entry.state = EntryState::Ready(value);
        entry.bytes = bytes;
        entry.recompute_secs = recompute_secs;
        entry.last_used = tick;
        entry.seen = seen;
        entry.cohorts = vec![(Arc::clone(heap), cohort)];
        if let Some(t) = &tenant {
            t.counters()
                .cache_live_bytes
                .fetch_add(bytes, Ordering::Relaxed);
        }
        entry.tenant = tenant;
        inner.stats.bytes_cached += bytes;
        inner.stats.entries += 1;
        let evicted = evict_under_pressure(&mut inner, fp, heap, cfg);
        drop(inner);
        self.ready.notify_all();
        evicted
    }

    /// Merge an appended delta into a ready entry: the reading cut found
    /// the entry at append mark `from`, recomputed only the tail, and
    /// offers the extended value covering `new_seen` items. The install
    /// is compare-and-swap on the mark — if another plan already merged
    /// (or the entry was evicted/replaced) the offer is withdrawn and the
    /// delta's heap charge released; the caller's own merged value is
    /// still correct to use either way (same source, same prefix).
    /// Returns `(merged, evictions)`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn merge_delta(
        &self,
        fp: Fingerprint,
        from: u64,
        value: Stored,
        bytes_delta: u64,
        items_delta: u64,
        new_seen: u64,
        heap: &Arc<SimHeap>,
        cfg: &CacheConfig,
    ) -> (bool, u64) {
        // Charge the delta before taking the cache lock (the heap lock is
        // always taken before the cache's, as in `complete`).
        let cohort = heap.scoped_cohort("cache.entry");
        let mut alloc = heap.thread_alloc();
        alloc.alloc_n(cohort, bytes_delta, items_delta.max(1));
        alloc.flush();
        drop(alloc);

        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let merged = match inner.entries.get_mut(&fp) {
            Some(e) if matches!(e.state, EntryState::Ready(_)) && e.seen == Some(from) => {
                e.state = EntryState::Ready(value);
                e.bytes += bytes_delta;
                e.seen = Some(new_seen);
                e.last_used = tick;
                e.cohorts.push((Arc::clone(heap), cohort));
                // Delta bytes stay attributed to the entry's producing
                // tenant — the entry is one budget unit however many
                // merges grow it.
                if let Some(t) = &e.tenant {
                    t.counters()
                        .cache_live_bytes
                        .fetch_add(bytes_delta, Ordering::Relaxed);
                }
                true
            }
            _ => false,
        };
        let evicted = if merged {
            inner.stats.bytes_cached += bytes_delta;
            inner.stats.delta_merges += 1;
            inner.stats.delta_items += items_delta;
            evict_under_pressure(&mut inner, fp, heap, cfg)
        } else {
            0
        };
        drop(inner);
        if !merged {
            // CAS failed: the charged delta bytes have no owning entry.
            heap.release_cohort(cohort);
        }
        (merged, evicted)
    }

    /// Drop the entry for `fp` if it is ready, releasing its heap cohort
    /// — the [`Dataset::uncache`](crate::api::plan::Dataset::uncache)
    /// path. In-flight entries are left to their claimant.
    pub fn remove(&self, fp: Fingerprint) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if matches!(
            inner.entries.get(&fp),
            Some(Entry {
                state: EntryState::Ready(_),
                ..
            })
        ) {
            release_entry(&mut inner, fp);
            true
        } else {
            false
        }
    }

    /// Evict every ready entry (in-flight claims are left to their
    /// owners). Cohorts are released; statistics other than
    /// `bytes_cached`/`entries` are preserved.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        let ready: Vec<Fingerprint> = inner
            .entries
            .iter()
            .filter(|(_, e)| matches!(e.state, EntryState::Ready(_)))
            .map(|(fp, _)| *fp)
            .collect();
        for fp in ready {
            release_entry(&mut inner, fp);
        }
    }
}

/// Remove a ready entry and release its simulated-heap cohorts, crediting
/// the owning tenant's live-cache bytes (and counting the eviction on its
/// scoreboard) when the entry was produced governed.
fn release_entry(inner: &mut CacheInner, fp: Fingerprint) {
    if let Some(e) = inner.entries.remove(&fp) {
        inner.stats.bytes_cached = inner.stats.bytes_cached.saturating_sub(e.bytes);
        inner.stats.entries = inner.stats.entries.saturating_sub(1);
        if let Some(t) = &e.tenant {
            t.counters()
                .cache_live_bytes
                .fetch_sub(e.bytes, Ordering::Relaxed);
            t.counters()
                .cache_evicted_bytes
                .fetch_add(e.bytes, Ordering::Relaxed);
        }
        for (heap, cohort) in e.cohorts {
            heap.release_cohort(cohort);
        }
    }
}

/// Whether any of an entry's bytes are charged to `heap`.
fn entry_on_heap(e: &Entry, heap: &Arc<SimHeap>) -> bool {
    e.cohorts.iter().any(|(h, _)| Arc::ptr_eq(h, heap))
}

/// Pick the next eviction victim: least-recently-used first,
/// cheapest-to-recompute first among equals, never the protected (just
/// inserted) entry, and — when `heap` is given — only entries charged to
/// that heap (evicting another heap's entries would not relieve it).
fn pick_victim(
    inner: &CacheInner,
    protect: Fingerprint,
    heap: Option<&Arc<SimHeap>>,
) -> Option<Fingerprint> {
    inner
        .entries
        .iter()
        .filter(|(fp, e)| {
            **fp != protect
                && matches!(e.state, EntryState::Ready(_))
                && heap.is_none_or(|h| entry_on_heap(e, h))
        })
        .min_by(|(_, a), (_, b)| {
            a.last_used
                .cmp(&b.last_used)
                .then(a.recompute_secs.total_cmp(&b.recompute_secs))
        })
        .map(|(fp, _)| *fp)
}

/// The eviction pass run after every insert. Two triggers:
///
/// * **capacity** — total cached bytes above [`CacheConfig::max_bytes`]:
///   evict (any heap) until back under the cap;
/// * **heap pressure** — the producing heap's occupancy at or above
///   `watermark × total_bytes`: release half the bytes cached *on that
///   heap*, giving its next minor/major collection real garbage to
///   reclaim (entries charged to other heaps are left alone — evicting
///   them would destroy warm state without relieving anything).
fn evict_under_pressure(
    inner: &mut CacheInner,
    protect: Fingerprint,
    heap: &Arc<SimHeap>,
    cfg: &CacheConfig,
) -> u64 {
    let mut evicted = 0u64;
    while inner.stats.bytes_cached > cfg.max_bytes {
        match pick_victim(inner, protect, None) {
            Some(fp) => {
                release_entry(inner, fp);
                evicted += 1;
            }
            None => break,
        }
    }
    let pressure = heap.enabled()
        && (heap.heap_used() as f64) >= cfg.watermark * heap.params().total_bytes as f64;
    if pressure {
        let on_heap = |inner: &CacheInner| -> u64 {
            inner
                .entries
                .values()
                .filter(|e| entry_on_heap(e, heap))
                .map(|e| e.bytes)
                .sum()
        };
        let target = on_heap(inner) / 2;
        while on_heap(inner) > target {
            match pick_victim(inner, protect, Some(heap)) {
                Some(fp) => {
                    release_entry(inner, fp);
                    evicted += 1;
                }
                None => break,
            }
        }
    }
    inner.stats.evictions += evicted;
    evicted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::HeapParams;

    fn store(v: Vec<Vec<i64>>) -> Stored {
        Arc::new(v)
    }

    fn cfg() -> CacheConfig {
        CacheConfig::default()
    }

    fn claim(cache: &MaterializationCache, fp: Fingerprint) -> Ticket<'_> {
        match cache.begin(fp) {
            Begin::Claimed(t) => t,
            Begin::Ready { .. } => panic!("expected a claim for {fp}"),
        }
    }

    #[test]
    fn identity_ordinals_are_first_seen_order() {
        let cache = MaterializationCache::new();
        assert_eq!(cache.identity_ordinal(0xAAAA), 0);
        assert_eq!(cache.identity_ordinal(0xBBBB), 1);
        assert_eq!(cache.identity_ordinal(0xAAAA), 0, "stable on re-registration");
    }

    #[test]
    fn miss_store_hit_roundtrip() {
        let cache = MaterializationCache::new();
        let heap = SimHeap::disabled();
        let fp = Fingerprint(42);
        let ticket = claim(&cache, fp);
        let v = store(vec![vec![1, 2], vec![3]]);
        cache.complete(ticket, v, 96, 3, 0.01, None, &heap, &cfg(), None);
        match cache.begin(fp) {
            Begin::Ready { value, waited, .. } => {
                assert!(!waited);
                // The caller confirms the read after its typed downcast
                // succeeds (see `CacheStage::execute`).
                cache.record_read(waited);
                let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                assert_eq!(*shards, vec![vec![1, 2], vec![3]]);
            }
            Begin::Claimed(_) => panic!("stored entry must hit"),
        }
        let s = cache.stats();
        assert_eq!((s.misses, s.hits, s.entries, s.bytes_cached), (1, 1, 1, 96));
        assert_eq!(s.type_conflicts, 0);
    }

    #[test]
    fn aborted_claim_recovers() {
        let cache = MaterializationCache::new();
        let fp = Fingerprint(7);
        drop(claim(&cache, fp)); // claimant "panicked"
        // The fingerprint is claimable again, not deadlocked in-flight.
        let t = claim(&cache, fp);
        let v = store(vec![vec![1]]);
        cache.complete(t, v, 16, 1, 0.0, None, &SimHeap::disabled(), &cfg(), None);
        assert!(cache.contains(fp));
    }

    #[test]
    fn waiters_share_one_in_flight_computation() {
        let cache = Arc::new(MaterializationCache::new());
        let heap = SimHeap::disabled();
        let fp = Fingerprint(9);
        let ticket = claim(&cache, fp);
        let waiter = {
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || match cache.begin(fp) {
                Begin::Ready { value, waited, .. } => {
                    cache.record_read(waited);
                    let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                    (shards.len(), waited)
                }
                Begin::Claimed(_) => panic!("waiter must not recompute"),
            })
        };
        // Give the waiter time to block on the in-flight entry.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let v = store(vec![vec![5], vec![6]]);
        cache.complete(ticket, v, 32, 2, 0.0, None, &heap, &cfg(), None);
        let (shards, waited) = waiter.join().unwrap();
        assert_eq!(shards, 2);
        assert!(waited);
        let s = cache.stats();
        assert_eq!((s.misses, s.shared_in_flight, s.hits), (1, 1, 0));
    }

    #[test]
    fn type_conflicts_are_counted_not_served() {
        let cache = MaterializationCache::new();
        let fp = Fingerprint(77);
        let t = claim(&cache, fp);
        let v = store(vec![vec![1]]);
        cache.complete(t, v, 16, 1, 0.0, None, &SimHeap::disabled(), &cfg(), None);
        match cache.begin(fp) {
            Begin::Ready { value, .. } => {
                assert!(value.downcast::<Vec<Vec<String>>>().is_err());
                cache.record_type_conflict();
            }
            Begin::Claimed(_) => panic!("stored entry must be found"),
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.type_conflicts), (0, 1));
    }

    #[test]
    fn capacity_eviction_is_lru_first() {
        let cache = MaterializationCache::new();
        let heap = SimHeap::disabled();
        let tight = CacheConfig {
            max_bytes: 100,
            ..CacheConfig::default()
        };
        let (a, b, c) = (Fingerprint(1), Fingerprint(2), Fingerprint(3));
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 0.5, None, &heap, &tight, None);
        let t = claim(&cache, b);
        cache.complete(t, store(vec![vec![2]]), 60, 1, 0.5, None, &heap, &tight, None);
        // Inserting B overflowed the cap: A (older) was evicted.
        assert!(!cache.contains(a));
        assert!(cache.contains(b));
        // Touch B, insert C: B is now most recent, but C is protected as
        // the fresh insert, so B survives only if the cap allows one —
        // it doesn't, and B is the only candidate.
        let _ = cache.begin(b);
        let t = claim(&cache, c);
        let v = store(vec![vec![3]]);
        let evicted = cache.complete(t, v, 60, 1, 0.5, None, &heap, &tight, None);
        assert_eq!(evicted, 1);
        assert!(!cache.contains(b));
        assert!(cache.contains(c));
        assert_eq!(cache.stats().evictions, 2);
    }

    #[test]
    fn heap_pressure_halves_cached_bytes() {
        // A tiny enabled heap filled past the watermark: the insert pass
        // must release cached cohorts back to it.
        let heap = SimHeap::new(HeapParams {
            total_bytes: 4 << 20,
            time_scale: 0.0,
            sample_every: 1e9,
            ..HeapParams::default()
        });
        let filler = heap.cohort("filler");
        let mut a = heap.thread_alloc();
        for _ in 0..3000 {
            a.alloc(filler, 1024); // ~3 MiB live of 4 MiB total
        }
        a.flush();
        let cache = MaterializationCache::new();
        let low = CacheConfig {
            watermark: 0.5,
            ..CacheConfig::default()
        };
        for i in 0..4 {
            let fp = Fingerprint(100 + i);
            let t = claim(&cache, fp);
            cache.complete(t, store(vec![vec![i as i64]]), 1000, 1, 0.1, None, &heap, &low, None);
        }
        let s = cache.stats();
        assert!(s.evictions > 0, "pressure must evict: {s:?}");
        assert!(s.bytes_cached < 4000, "cached bytes must shrink: {s:?}");
    }

    #[test]
    fn remove_and_clear_release_cohort_bytes() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let fp = Fingerprint(55);
        let t = claim(&cache, fp);
        cache.complete(t, store(vec![vec![1]]), 4096, 1, 0.0, None, &heap, &cfg(), None);
        assert_eq!(cache.stats().bytes_cached, 4096);
        assert!(cache.remove(fp));
        assert!(!cache.remove(fp), "second removal finds nothing");
        assert_eq!(cache.stats().bytes_cached, 0);
        let t = claim(&cache, fp);
        cache.complete(t, store(vec![vec![2]]), 64, 1, 0.0, None, &heap, &cfg(), None);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
        assert!(!cache.contains(fp));
    }

    #[test]
    fn delta_merge_extends_entry_and_cas_guards_races() {
        let cache = MaterializationCache::new();
        let heap = SimHeap::disabled();
        let fp = Fingerprint(91);
        let t = claim(&cache, fp);
        cache.complete(t, store(vec![vec![1, 2]]), 32, 2, 0.0, Some(2), &heap, &cfg(), None);
        let seen = match cache.begin(fp) {
            Begin::Ready { seen, waited, .. } => {
                cache.record_read(waited);
                seen
            }
            Begin::Claimed(_) => panic!("entry must be ready"),
        };
        assert_eq!(seen, Some(2), "append mark surfaces to readers");
        let (merged, _) =
            cache.merge_delta(fp, 2, store(vec![vec![1, 2], vec![3]]), 16, 1, 3, &heap, &cfg());
        assert!(merged);
        // A straggler still holding the pre-merge mark loses the CAS.
        let (merged, _) = cache.merge_delta(fp, 2, store(vec![vec![9]]), 16, 1, 3, &heap, &cfg());
        assert!(!merged, "stale mark must not clobber the merged entry");
        let s = cache.stats();
        assert_eq!((s.delta_merges, s.delta_items, s.bytes_cached), (1, 1, 48));
        match cache.begin(fp) {
            Begin::Ready { value, seen, .. } => {
                assert_eq!(seen, Some(3), "mark advances with the merge");
                let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                assert_eq!(*shards, vec![vec![1, 2], vec![3]]);
            }
            Begin::Claimed(_) => panic!("merged entry must stay ready"),
        }
    }
}
