//! The plan-aware materialization cache — cross-plan reuse of computed
//! subplan results, with pressure-aware eviction.
//!
//! The lazy [`Dataset`](crate::api::plan::Dataset) layer gave the
//! framework something the paper's per-class agent never had: whole plans
//! are structurally inspectable *before* they run. This module spends
//! that semantic information on a second framework-level optimization
//! (in the spirit of MANIMAL's pre-execution plan analysis and the reuse
//! family in Rao & Wang's semantics-aware-optimization taxonomy):
//! **identical plan prefixes are computed once**. A k-means driver that
//! re-derives its point dataset every Lloyd iteration, or two concurrent
//! tenants collecting the same source + stage chain, share one
//! materialization instead of re-running — and re-allocating — the same
//! subplan.
//!
//! Moving parts:
//!
//! * [`fingerprint`] — structural prefix fingerprints, computed by the
//!   planner during lowering (source identity + stage kinds/names +
//!   closure registration order + [`OptimizeMode`]).
//! * [`MaterializationCache`] — one per [`Runtime`] session: finished
//!   shard outputs keyed by fingerprint. Entries are charged to a
//!   dedicated scoped [`SimHeap`] cohort (`"cache.entry"`), so cached
//!   bytes are *live simulated heap* — the cache competes for the same
//!   memory the paper's GC study measures, which is exactly why eviction
//!   is pressure-aware.
//! * **In-flight deduplication** — the first plan to miss a fingerprint
//!   claims the entry and computes; concurrent plans racing on the same
//!   uncached prefix block on the entry and reuse the one result
//!   ([`CacheStats::shared_in_flight`] counts them). A claimant that
//!   panics aborts its claim on unwind, so waiters recover and compute.
//! * **Cost-aware tiered eviction** (see [`tier`]) — when the producing
//!   job's simulated heap occupancy crosses [`CacheConfig::watermark`]
//!   (or hot-tier bytes exceed [`CacheConfig::max_bytes`]), victims are
//!   chosen by lowest *keep score* — staleness-decayed recompute cost
//!   per resident byte — and each victim is then either **spilled** to
//!   the cold tier (its heap cohorts are released, so spilled bytes
//!   genuinely relieve the heap, and the next read *reloads* it at a
//!   simulated `bytes × reload_secs_per_byte` cost) or **dropped**
//!   outright when recomputing is cheaper than reloading. Evicted
//!   entries are therefore *not* discarded unconditionally any more:
//!   only entries the heuristic judges cheap or stale die; expensive
//!   prefixes survive on the spill tier. Recompute costs prefer the
//!   per-fingerprint observed compute times in the session's
//!   [`StatsStore`](crate::stats::StatsStore) (attached as the cache's
//!   cost feed) over the wall time measured at materialization, and
//!   survivors of a triggered pass are counted as explicit keep
//!   decisions, so the keep/spill/drop mix is observable in
//!   [`CacheStats`].
//!
//! The cache is populated and read **only at explicit
//! [`Dataset::cache`](crate::api::plan::Dataset::cache) cut points**: a
//! plan that never marks a cut never probes the cache, so eager jobs and
//! un-annotated plans are byte-for-byte unaffected. Read-through is
//! automatic *across* plans: any plan marking a cut whose prefix
//! fingerprint matches a stored entry reuses it, whichever tenant stored
//! it.
//!
//! [`OptimizeMode`]: crate::api::config::OptimizeMode
//! [`Runtime`]: crate::api::Runtime
//! [`SimHeap`]: crate::memsim::SimHeap

pub mod fingerprint;
pub mod tier;

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::api::config::CacheConfig;
use crate::govern::TenantHandle;
use crate::memsim::{CohortId, SimHeap};
use crate::stats::StatsStore;
use crate::trace::metrics::Histogram;
use crate::trace::{Obs, SpanKind};

use tier::{decide, keep_score, EntryCost, SpillEntry, SpillStore};

pub use fingerprint::Fingerprint;
pub use tier::{Residency, TierDecision};

/// Per-element bookkeeping overhead charged for a cached element beside
/// its [`HeapSized`](crate::api::traits::HeapSized) payload (the shard
/// slot, mirroring the collector's list-slot accounting).
pub const ENTRY_SLOT_BYTES: u64 = 16;

/// Session-cumulative cache statistics (the numbers the acceptance
/// criteria and the harness report read).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Cut-point reads served from a ready entry without waiting.
    pub hits: u64,
    /// Cut-point reads that found no entry and computed the prefix.
    pub misses: u64,
    /// Cut-point reads that blocked on another plan's in-flight
    /// computation and shared its result (the dedup observable).
    pub shared_in_flight: u64,
    /// Ready entries whose stored type did not match the reading cut's
    /// element type (a fingerprint collision across types — the reader
    /// recomputed without touching the entry).
    pub type_conflicts: u64,
    /// Entries that left the hot tier under pressure (cumulative;
    /// spills + drops — see `spills` for the split).
    pub evictions: u64,
    /// Hot-tier victims moved to the cold spill tier instead of being
    /// dropped (a subset of `evictions`).
    pub spills: u64,
    /// Reads served from the spill tier at simulated reload cost
    /// instead of recomputing the prefix.
    pub reloads: u64,
    /// Payload bytes re-charged to the heap by reloads (cumulative).
    pub reload_bytes: u64,
    /// Entries dropped *from the cold tier* to make room for newer
    /// spills (not counted in `evictions`, which tracks hot-tier
    /// departures only).
    pub spill_evictions: u64,
    /// Bytes currently resident in the spill tier (these bytes hold no
    /// heap cohorts — spilling released them).
    pub bytes_spilled: u64,
    /// Entries currently resident in the spill tier.
    pub spill_entries: usize,
    /// Fingerprints recomputed through the claim path after pressure
    /// dropped them from either tier — the recomputation a better
    /// keep/spill decision would have avoided (explicit `remove`/
    /// `clear` calls do not count).
    pub rematerializations: u64,
    /// Elements recomputed by those rematerializations.
    pub remat_items: u64,
    /// Keep decisions: entries examined by a triggered eviction pass
    /// that survived it.
    pub decisions_keep: u64,
    /// Spill decisions made by the tier heuristic.
    pub decisions_spill: u64,
    /// Drop decisions made by the tier heuristic (hot-tier victims).
    pub decisions_drop: u64,
    /// Cold-tier entries aged out: their staleness-decayed recompute
    /// value fell below their reload cost, so keeping them spilled no
    /// longer paid for the tier bytes they held (see
    /// [`tier::SpillStore`]). Not counted in `spill_evictions`, which
    /// tracks capacity-driven cold drops.
    pub decisions_aged_out: u64,
    /// Victim decisions whose recompute-cost input came from a
    /// [`StatsStore`] observed-compute-time sample rather than only the
    /// cache's own materialization stopwatch.
    pub stats_fed_decisions: u64,
    /// Append-delta merges: a cut point found a ready entry whose
    /// append-aware source (see
    /// [`InputSource::append_len`](crate::api::InputSource::append_len))
    /// had grown, recomputed only the appended tail, and merged it into
    /// the entry — a prefix hit *plus* a delta, never a full recompute.
    pub delta_merges: u64,
    /// Elements appended into existing entries via delta merges.
    pub delta_items: u64,
    /// Bytes currently cached in the hot tier (live `cache.entry`
    /// cohort bytes).
    pub bytes_cached: u64,
    /// Ready hot-tier entries currently stored.
    pub entries: usize,
}

/// What one plan did to the cache (the per-plan slice of [`CacheStats`],
/// reported in [`PlanReport::cache`](crate::api::plan::PlanReport) and on
/// the consuming stage's
/// [`FlowMetrics::cache`](crate::coordinator::pipeline::FlowMetrics)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheActivity {
    pub hits: u64,
    pub misses: u64,
    pub shared_in_flight: u64,
    /// Evictions this plan's inserts triggered.
    pub evictions: u64,
    /// Bytes this plan inserted into the cache.
    pub bytes_inserted: u64,
    /// Reads this plan served from the spill tier (each promoted the
    /// entry back to the hot tier, or found a racing reader already
    /// had).
    pub reloads: u64,
    /// Payload bytes this plan's reloads re-charged to its heap.
    pub reload_bytes: u64,
}

impl CacheActivity {
    pub(crate) fn add(&mut self, other: &CacheActivity) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.shared_in_flight += other.shared_in_flight;
        self.evictions += other.evictions;
        self.bytes_inserted += other.bytes_inserted;
        self.reloads += other.reloads;
        self.reload_bytes += other.reload_bytes;
    }
}

/// A consistency snapshot for tests ([`MaterializationCache::audit`]):
/// tier byte totals recomputed from the ground truth rather than the
/// running [`CacheStats`] counters.
#[doc(hidden)]
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheAudit {
    /// Σ bytes across ready hot-tier entries.
    pub hot_bytes: u64,
    pub hot_entries: usize,
    /// Claimed fingerprints currently being computed.
    pub in_flight: usize,
    /// Σ bytes across cold-tier entries.
    pub spill_bytes: u64,
    pub spill_entries: usize,
    /// Σ live bytes across hot entries' heap cohorts — equals
    /// `hot_bytes` exactly on an enabled heap (spilled entries hold no
    /// cohorts).
    pub cohort_bytes: u64,
    /// Fingerprints resident in both tiers (the tier invariant: always
    /// zero).
    pub double_resident: usize,
}

/// Type-erased cached shard outputs (`Arc<Vec<Vec<T>>>` behind `Any`; the
/// cut point downcasts back to its concrete element type).
pub(crate) type Stored = Arc<dyn Any + Send + Sync>;

enum EntryState {
    /// A plan claimed this fingerprint and is computing the prefix.
    InFlight,
    Ready(Stored),
}

struct Entry {
    state: EntryState,
    bytes: u64,
    /// Elements stored in the entry's value (Σ shard lengths) — what a
    /// future rematerialization would have to recompute if pressure
    /// drops this entry.
    items: u64,
    /// Wall seconds the producing plan spent computing the prefix — the
    /// recompute cost the eviction policy protects (the cost feed's
    /// observed per-prefix compute time overrides it when larger).
    recompute_secs: f64,
    /// LRU clock value of the last read/insert.
    last_used: u64,
    /// Source items this entry's value covers, when the producing cut's
    /// source was append-aware — the high-water mark delta merges compare
    /// against. `None` for fixed sources (no delta maintenance).
    seen: Option<u64>,
    /// The simulated-heap cohorts holding this entry's bytes live (the
    /// original insert plus one per delta merge; all released on
    /// eviction/removal).
    cohorts: Vec<(Arc<SimHeap>, CohortId)>,
    /// The tenant whose plan produced this entry, when it ran governed:
    /// the entry's bytes (including later delta merges) count against
    /// that tenant's live-cache budget until release (see
    /// [`crate::govern`]).
    tenant: Option<Arc<TenantHandle>>,
}

struct CacheInner {
    entries: HashMap<Fingerprint, Entry>,
    /// The cold tier (see [`tier`]). Lives under the same mutex as the
    /// hot map, so tier membership is atomic: a fingerprint is never
    /// resident in both.
    spill: SpillStore,
    /// Fingerprints pressure dropped from either tier (→ items at drop
    /// time): when one comes back through the claim path, the recompute
    /// is counted as a rematerialization.
    dropped: HashMap<Fingerprint, u64>,
    /// Raw identity → first-seen registration ordinal (what fingerprints
    /// hash, making them session-order-stable rather than address-bound).
    identity: HashMap<u64, u64>,
    next_ordinal: u64,
    stats: CacheStats,
    /// LRU clock.
    tick: u64,
}

/// Outcome of [`MaterializationCache::begin`].
pub(crate) enum Begin<'c> {
    /// A ready entry was found (`waited` → only after blocking on another
    /// plan's in-flight computation). `seen` is the entry's append
    /// high-water mark, when its source was append-aware — the reader
    /// compares it against the source's current length to decide whether
    /// a delta merge is due.
    Ready {
        value: Stored,
        waited: bool,
        seen: Option<u64>,
    },
    /// The fingerprint is resident in the cold spill tier: the caller
    /// gets the value immediately and — after its typed downcast
    /// succeeds — calls [`MaterializationCache::complete_reload`] to
    /// charge the simulated reload and promote the entry back to the
    /// hot tier. A failed downcast takes the `type_conflicts` recompute
    /// path instead: a mistyped entry is never served, spilled or not.
    Spilled {
        value: Stored,
        seen: Option<u64>,
        bytes: u64,
        items: u64,
    },
    /// This caller claimed the fingerprint: compute the prefix, then
    /// [`MaterializationCache::complete`] the ticket (dropping it without
    /// completing — e.g. on unwind — aborts the claim and wakes waiters).
    Claimed(Ticket<'c>),
}

/// An in-flight claim on a fingerprint (see [`Begin::Claimed`]).
pub(crate) struct Ticket<'c> {
    cache: &'c MaterializationCache,
    fp: Fingerprint,
    done: bool,
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        if !self.done {
            // The claimant unwound before completing: withdraw the
            // in-flight entry so waiters recover and compute themselves.
            let mut inner = self.cache.inner.lock().unwrap();
            if matches!(
                inner.entries.get(&self.fp),
                Some(Entry {
                    state: EntryState::InFlight,
                    ..
                })
            ) {
                inner.entries.remove(&self.fp);
            }
            drop(inner);
            self.cache.ready.notify_all();
        }
    }
}

/// The session-level materialization cache (owned by
/// [`Runtime`](crate::api::Runtime), shared by every plan on the
/// session). See the [module docs](self).
pub struct MaterializationCache {
    inner: Mutex<CacheInner>,
    ready: Condvar,
    /// The session's statistics store, attached once by the owning
    /// `Runtime`: keep/spill/drop decisions prefer its per-fingerprint
    /// observed compute times over the cache's own stopwatch.
    cost_feed: OnceLock<Arc<StatsStore>>,
    /// The session's observability handles (see [`crate::trace`]),
    /// attached once by the owning `Runtime`. Every tier transition
    /// emits a trace event at the exact line that bumps the matching
    /// [`CacheStats`] counter, so span counts reconcile with the stats.
    obs: OnceLock<CacheObs>,
}

/// Pre-resolved instruments so the hot paths never touch the registry
/// map: the shared [`Obs`] plus the cache's own metric handles.
struct CacheObs {
    obs: Obs,
    /// `cache.reload_us` — simulated reload latency per cold-tier read.
    reload_us: Arc<Histogram>,
}

impl Default for MaterializationCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MaterializationCache {
    pub fn new() -> Self {
        MaterializationCache {
            inner: Mutex::new(CacheInner {
                entries: HashMap::new(),
                spill: SpillStore::default(),
                dropped: HashMap::new(),
                identity: HashMap::new(),
                next_ordinal: 0,
                stats: CacheStats::default(),
                tick: 0,
            }),
            ready: Condvar::new(),
            cost_feed: OnceLock::new(),
            obs: OnceLock::new(),
        }
    }

    /// Attach the session's tracer + metrics registry (see
    /// [`crate::trace`]). Set once by the owning
    /// [`Runtime`](crate::api::Runtime); later calls are ignored.
    pub fn attach_obs(&self, obs: Obs) {
        let reload_us = obs.metrics.histogram("cache.reload_us");
        let _ = self.obs.set(CacheObs { obs, reload_us });
    }

    /// Attach the session's statistics store as the eviction cost feed
    /// (see [`StatsStore::prefix_cost`]). Set once by the owning
    /// [`Runtime`](crate::api::Runtime); later calls are ignored.
    pub fn attach_cost_feed(&self, stats: Arc<StatsStore>) {
        let _ = self.cost_feed.set(stats);
    }

    /// Record one observed prefix materialization into the cost feed
    /// (no-op when no feed is attached).
    pub(crate) fn note_prefix_cost(&self, fp: Fingerprint, compute_secs: f64, output_bytes: u64) {
        if let Some(stats) = self.cost_feed.get() {
            stats.record_prefix_cost(fp.0, compute_secs, output_bytes);
        }
    }

    /// Map a raw identity (a source address, a closure `Arc` pointer) to
    /// its session registration ordinal, assigned in first-seen order.
    /// Fingerprints hash ordinals, never raw addresses — see
    /// [`fingerprint`].
    pub fn identity_ordinal(&self, raw: u64) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&ord) = inner.identity.get(&raw) {
            return ord;
        }
        let ord = inner.next_ordinal;
        inner.next_ordinal += 1;
        inner.identity.insert(raw, ord);
        ord
    }

    /// Snapshot the session-cumulative statistics.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Whether a ready *hot-tier* entry exists for `fp` (tests and
    /// diagnostics; spilled entries answer false — see
    /// [`MaterializationCache::residency`]).
    pub fn contains(&self, fp: Fingerprint) -> bool {
        matches!(
            self.inner.lock().unwrap().entries.get(&fp),
            Some(Entry {
                state: EntryState::Ready(_),
                ..
            })
        )
    }

    /// Where `fp` currently lives in the two-tier store (surfaced in
    /// `explain()` cut-point lines).
    pub fn residency(&self, fp: Fingerprint) -> Residency {
        let inner = self.inner.lock().unwrap();
        match inner.entries.get(&fp) {
            Some(Entry {
                state: EntryState::Ready(_),
                ..
            }) => Residency::Hot,
            Some(Entry {
                state: EntryState::InFlight,
                ..
            }) => Residency::InFlight,
            None if inner.spill.contains(&fp) => Residency::Spilled,
            None => Residency::Absent,
        }
    }

    /// A consistency snapshot recomputed from ground truth (the entry
    /// maps and live cohort bytes) rather than the running counters —
    /// what the tier-invariant property tests check `stats()` against.
    #[doc(hidden)]
    pub fn audit(&self) -> CacheAudit {
        let inner = self.inner.lock().unwrap();
        let mut a = CacheAudit::default();
        for (fp, e) in &inner.entries {
            match &e.state {
                EntryState::Ready(_) => {
                    a.hot_bytes += e.bytes;
                    a.hot_entries += 1;
                    for (heap, cohort) in &e.cohorts {
                        a.cohort_bytes += heap.cohort_live(*cohort);
                    }
                }
                EntryState::InFlight => a.in_flight += 1,
            }
            if inner.spill.contains(fp) {
                a.double_resident += 1;
            }
        }
        a.spill_bytes = inner.spill.bytes;
        a.spill_entries = inner.spill.entries.len();
        a
    }

    /// Resolve a cut point: return the ready entry, wait out another
    /// plan's in-flight computation, or claim the fingerprint for this
    /// caller to compute. Misses are counted here; successful reads are
    /// counted by the caller via [`MaterializationCache::record_read`]
    /// *after* its typed downcast succeeds (a type conflict is not a
    /// served read).
    pub(crate) fn begin(&self, fp: Fingerprint) -> Begin<'_> {
        let mut inner = self.inner.lock().unwrap();
        let mut waited = false;
        loop {
            let ready = match inner.entries.get(&fp) {
                Some(Entry {
                    state: EntryState::Ready(v),
                    seen,
                    ..
                }) => Some((Arc::clone(v), *seen)),
                Some(Entry {
                    state: EntryState::InFlight,
                    ..
                }) => {
                    waited = true;
                    inner = self.ready.wait(inner).unwrap();
                    continue;
                }
                None => None,
            };
            return match ready {
                Some((value, seen)) => {
                    inner.tick += 1;
                    let tick = inner.tick;
                    if let Some(e) = inner.entries.get_mut(&fp) {
                        e.last_used = tick;
                    }
                    Begin::Ready {
                        value,
                        waited,
                        seen,
                    }
                }
                None if inner.spill.contains(&fp) => {
                    // Cold but resident: serve from the spill tier (not
                    // a miss — the prefix will not recompute).
                    inner.tick += 1;
                    let tick = inner.tick;
                    let s = inner.spill.get_mut(&fp).expect("spill residency checked");
                    s.last_used = tick;
                    Begin::Spilled {
                        value: Arc::clone(&s.value),
                        seen: s.seen,
                        bytes: s.bytes,
                        items: s.items,
                    }
                }
                None => {
                    inner.entries.insert(
                        fp,
                        Entry {
                            state: EntryState::InFlight,
                            bytes: 0,
                            items: 0,
                            recompute_secs: 0.0,
                            last_used: 0,
                            seen: None,
                            cohorts: Vec::new(),
                            tenant: None,
                        },
                    );
                    inner.stats.misses += 1;
                    if let Some(o) = self.obs.get() {
                        o.obs.tracer.instant(SpanKind::CacheMiss, fp.0, 0);
                    }
                    Begin::Claimed(Ticket {
                        cache: self,
                        fp,
                        done: false,
                    })
                }
            };
        }
    }

    /// Count one successfully served read (`waited` → it shared another
    /// plan's in-flight computation instead of finding the entry ready).
    pub(crate) fn record_read(&self, waited: bool) {
        let mut inner = self.inner.lock().unwrap();
        if waited {
            inner.stats.shared_in_flight += 1;
        } else {
            inner.stats.hits += 1;
        }
        drop(inner);
        if let Some(o) = self.obs.get() {
            let kind = if waited {
                SpanKind::CacheShared
            } else {
                SpanKind::CacheHit
            };
            o.obs.tracer.instant(kind, 0, 0);
        }
    }

    /// Count one cross-type fingerprint collision (the reader recomputed
    /// without being served).
    pub(crate) fn record_type_conflict(&self) {
        self.inner.lock().unwrap().stats.type_conflicts += 1;
    }

    /// Publish a claimed entry: charge its bytes to a fresh scoped cohort
    /// on the producing job's heap (cached bytes are live simulated
    /// heap), store the value, run pressure-aware eviction, and wake any
    /// plans waiting on the fingerprint. `seen` is the append high-water
    /// mark for append-aware sources (`None` for fixed sources). When the
    /// producing plan ran governed, `tenant` owns the entry's bytes: they
    /// are charged to its live-cache counter now and credited back on
    /// release. Returns the number of entries evicted by this insert.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn complete(
        &self,
        mut ticket: Ticket<'_>,
        value: Stored,
        bytes: u64,
        items: u64,
        recompute_secs: f64,
        seen: Option<u64>,
        heap: &Arc<SimHeap>,
        cfg: &CacheConfig,
        tenant: Option<Arc<TenantHandle>>,
    ) -> u64 {
        ticket.done = true;
        let fp = ticket.fp;
        // Account before taking the cache lock: the allocation may run a
        // simulated GC, which takes the heap lock (never the cache's).
        let cohort = heap.scoped_cohort("cache.entry");
        let mut alloc = heap.thread_alloc();
        alloc.alloc_n(cohort, bytes, items.max(1));
        alloc.flush();
        drop(alloc);

        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner
            .entries
            .get_mut(&fp)
            .expect("claimed entry present until completed or aborted");
        entry.state = EntryState::Ready(value);
        entry.bytes = bytes;
        entry.items = items;
        entry.recompute_secs = recompute_secs;
        entry.last_used = tick;
        entry.seen = seen;
        entry.cohorts = vec![(Arc::clone(heap), cohort)];
        if let Some(t) = &tenant {
            t.counters()
                .cache_live_bytes
                .fetch_add(bytes, Ordering::Relaxed);
        }
        entry.tenant = tenant;
        inner.stats.bytes_cached += bytes;
        inner.stats.entries += 1;
        if inner.dropped.remove(&fp).is_some() {
            // Pressure dropped this fingerprint earlier and the claim
            // path just recomputed it — the cost a keep or spill
            // decision would have avoided.
            inner.stats.rematerializations += 1;
            inner.stats.remat_items += items;
        }
        let feed = self.cost_feed.get().map(|s| s.as_ref());
        let obs = self.obs.get();
        let evicted = evict_under_pressure(&mut inner, fp, heap, cfg, feed, obs);
        drop(inner);
        self.ready.notify_all();
        if let Some(o) = obs {
            // One materialize span per completed claim, with the
            // simulated duration the producing plan measured.
            o.obs
                .tracer
                .record_with_dur(SpanKind::CacheMaterialize, recompute_secs, bytes, items);
        }
        evicted
    }

    /// Serve a read from the spill tier: charge the simulated reload —
    /// the payload re-enters the heap as a fresh `cache.entry` cohort,
    /// plus transient `cache.reload` scratch traffic of the same size
    /// (the deserialization garbage), so the GC-pressure metric sees
    /// the reload — then promote the entry back to the hot tier. Racing
    /// readers may each see [`Begin::Spilled`] for the same
    /// fingerprint: the first promotes; later ones find the entry
    /// already hot (or gone) and release their duplicate charge. Every
    /// caller counts as one reload — each physically simulated one.
    /// Returns `(promoted, evictions)`.
    pub(crate) fn complete_reload(
        &self,
        fp: Fingerprint,
        bytes: u64,
        items: u64,
        heap: &Arc<SimHeap>,
        cfg: &CacheConfig,
    ) -> (bool, u64) {
        // Charge before taking the cache lock (heap before cache, as in
        // `complete`: the allocation may run a simulated GC, which
        // takes the heap lock and never the cache's).
        let cohort = heap.scoped_cohort("cache.entry");
        let scratch = heap.cohort("cache.reload");
        let mut alloc = heap.thread_alloc();
        alloc.alloc_n(cohort, bytes, items.max(1));
        alloc.scratch(scratch, bytes);
        alloc.flush();
        drop(alloc);

        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.stats.reloads += 1;
        inner.stats.reload_bytes += bytes;
        let promoted = match inner.spill.take(&fp) {
            Some(s) => {
                inner.stats.bytes_spilled = inner.stats.bytes_spilled.saturating_sub(s.bytes);
                inner.stats.spill_entries = inner.stats.spill_entries.saturating_sub(1);
                if let Some(t) = &s.tenant {
                    t.counters()
                        .cache_spill_bytes
                        .fetch_sub(s.bytes, Ordering::Relaxed);
                    t.counters()
                        .cache_live_bytes
                        .fetch_add(s.bytes, Ordering::Relaxed);
                }
                inner.stats.bytes_cached += s.bytes;
                inner.stats.entries += 1;
                inner.entries.insert(
                    fp,
                    Entry {
                        state: EntryState::Ready(s.value),
                        bytes: s.bytes,
                        items: s.items,
                        recompute_secs: s.recompute_secs,
                        last_used: tick,
                        seen: s.seen,
                        cohorts: vec![(Arc::clone(heap), cohort)],
                        tenant: s.tenant,
                    },
                );
                true
            }
            None => false,
        };
        let evicted = if promoted {
            let feed = self.cost_feed.get().map(|s| s.as_ref());
            evict_under_pressure(&mut inner, fp, heap, cfg, feed, self.obs.get())
        } else {
            0
        };
        drop(inner);
        if !promoted {
            // Lost the promotion race (or the entry was cold-dropped in
            // between): the duplicate charge has no owning entry.
            heap.release_cohort(cohort);
        }
        if let Some(o) = self.obs.get() {
            // One reload event per physically simulated reload — the
            // same per-call granularity as `CacheStats::reloads`.
            o.reload_us.record_secs(bytes as f64 * cfg.reload_secs_per_byte);
            o.obs.tracer.instant(SpanKind::CacheReload, bytes, items);
        }
        (promoted, evicted)
    }

    /// Merge an appended delta into a ready entry: the reading cut found
    /// the entry at append mark `from`, recomputed only the tail, and
    /// offers the extended value covering `new_seen` items. The install
    /// is compare-and-swap on the mark — if another plan already merged
    /// (or the entry was evicted/replaced) the offer is withdrawn and the
    /// delta's heap charge released; the caller's own merged value is
    /// still correct to use either way (same source, same prefix).
    /// Returns `(merged, evictions)`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn merge_delta(
        &self,
        fp: Fingerprint,
        from: u64,
        value: Stored,
        bytes_delta: u64,
        items_delta: u64,
        new_seen: u64,
        heap: &Arc<SimHeap>,
        cfg: &CacheConfig,
    ) -> (bool, u64) {
        // Charge the delta before taking the cache lock (the heap lock is
        // always taken before the cache's, as in `complete`).
        let cohort = heap.scoped_cohort("cache.entry");
        let mut alloc = heap.thread_alloc();
        alloc.alloc_n(cohort, bytes_delta, items_delta.max(1));
        alloc.flush();
        drop(alloc);

        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let merged = match inner.entries.get_mut(&fp) {
            Some(e) if matches!(e.state, EntryState::Ready(_)) && e.seen == Some(from) => {
                e.state = EntryState::Ready(value);
                e.bytes += bytes_delta;
                e.items += items_delta;
                e.seen = Some(new_seen);
                e.last_used = tick;
                e.cohorts.push((Arc::clone(heap), cohort));
                // Delta bytes stay attributed to the entry's producing
                // tenant — the entry is one budget unit however many
                // merges grow it.
                if let Some(t) = &e.tenant {
                    t.counters()
                        .cache_live_bytes
                        .fetch_add(bytes_delta, Ordering::Relaxed);
                }
                true
            }
            _ => false,
        };
        let evicted = if merged {
            inner.stats.bytes_cached += bytes_delta;
            inner.stats.delta_merges += 1;
            inner.stats.delta_items += items_delta;
            let feed = self.cost_feed.get().map(|s| s.as_ref());
            evict_under_pressure(&mut inner, fp, heap, cfg, feed, self.obs.get())
        } else {
            0
        };
        drop(inner);
        if !merged {
            // CAS failed: the charged delta bytes have no owning entry.
            heap.release_cohort(cohort);
        }
        (merged, evicted)
    }

    /// Drop the entry for `fp` from whichever tier holds it, releasing
    /// any heap cohorts — the
    /// [`Dataset::uncache`](crate::api::plan::Dataset::uncache) path.
    /// In-flight entries are left to their claimant. A deliberate
    /// removal is not a pressure drop: a later recompute does not count
    /// as a rematerialization.
    pub fn remove(&self, fp: Fingerprint) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if matches!(
            inner.entries.get(&fp),
            Some(Entry {
                state: EntryState::Ready(_),
                ..
            })
        ) {
            release_entry(&mut inner, fp);
            true
        } else if inner.spill.contains(&fp) {
            release_spilled(&mut inner, fp);
            true
        } else {
            false
        }
    }

    /// Evict every ready entry from both tiers (in-flight claims are
    /// left to their owners). Cohorts are released; statistics other
    /// than the residency gauges are preserved.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        let ready: Vec<Fingerprint> = inner
            .entries
            .iter()
            .filter(|(_, e)| matches!(e.state, EntryState::Ready(_)))
            .map(|(fp, _)| *fp)
            .collect();
        for fp in ready {
            release_entry(&mut inner, fp);
        }
        let cold: Vec<Fingerprint> = inner.spill.entries.keys().copied().collect();
        for fp in cold {
            release_spilled(&mut inner, fp);
        }
        inner.dropped.clear();
    }
}

/// Remove a ready entry and release its simulated-heap cohorts, crediting
/// the owning tenant's live-cache bytes (and counting the eviction on its
/// scoreboard) when the entry was produced governed.
fn release_entry(inner: &mut CacheInner, fp: Fingerprint) {
    if let Some(e) = inner.entries.remove(&fp) {
        inner.stats.bytes_cached = inner.stats.bytes_cached.saturating_sub(e.bytes);
        inner.stats.entries = inner.stats.entries.saturating_sub(1);
        if let Some(t) = &e.tenant {
            t.counters()
                .cache_live_bytes
                .fetch_sub(e.bytes, Ordering::Relaxed);
            t.counters()
                .cache_evicted_bytes
                .fetch_add(e.bytes, Ordering::Relaxed);
        }
        for (heap, cohort) in e.cohorts {
            heap.release_cohort(cohort);
        }
    }
}

/// Remove a cold-tier entry, crediting the owning tenant's spill bytes
/// (and counting the departure as evicted bytes on its scoreboard).
/// Returns the entry's item count for the caller's remat bookkeeping.
fn release_spilled(inner: &mut CacheInner, fp: Fingerprint) -> Option<u64> {
    let s = inner.spill.take(&fp)?;
    inner.stats.bytes_spilled = inner.stats.bytes_spilled.saturating_sub(s.bytes);
    inner.stats.spill_entries = inner.stats.spill_entries.saturating_sub(1);
    if let Some(t) = &s.tenant {
        t.counters()
            .cache_spill_bytes
            .fetch_sub(s.bytes, Ordering::Relaxed);
        t.counters()
            .cache_evicted_bytes
            .fetch_add(s.bytes, Ordering::Relaxed);
    }
    Some(s.items)
}

/// Whether any of an entry's bytes are charged to `heap`.
fn entry_on_heap(e: &Entry, heap: &Arc<SimHeap>) -> bool {
    e.cohorts.iter().any(|(h, _)| Arc::ptr_eq(h, heap))
}

/// The heuristic inputs for one hot entry: the recompute cost is the
/// larger of the cache's own materialization stopwatch and the cost
/// feed's per-fingerprint observed compute time (when a sample exists).
fn entry_cost(fp: Fingerprint, e: &Entry, tick: u64, feed: Option<&StatsStore>) -> EntryCost {
    let mut recompute_secs = e.recompute_secs;
    let mut stats_fed = false;
    if let Some(store) = feed {
        if let Some(pc) = store.prefix_cost(fp.0) {
            if pc.samples > 0 {
                // Conservative: protect the prefix by its worst observed
                // materialization, not just the latest.
                recompute_secs = recompute_secs.max(pc.peak_secs);
                stats_fed = true;
            }
        }
    }
    EntryCost {
        recompute_secs,
        bytes: e.bytes,
        age: tick.saturating_sub(e.last_used),
        stats_fed,
    }
}

/// Pick the next eviction victim: the lowest keep score — staleness-
/// decayed recompute cost per resident byte — never the protected (just
/// inserted) entry, never an in-flight claim, and — when `heap` is
/// given — only entries charged to that heap (evicting another heap's
/// entries would not relieve it). Among equal costs and sizes the decay
/// term makes this least-recently-used first, and among equal ages the
/// cheapest-to-recompute goes first: the pre-tiered ordering is the
/// degenerate case. Ties break on the fingerprint for determinism.
fn pick_victim(
    inner: &CacheInner,
    protect: Fingerprint,
    heap: Option<&Arc<SimHeap>>,
    cfg: &CacheConfig,
    feed: Option<&StatsStore>,
) -> Option<Fingerprint> {
    inner
        .entries
        .iter()
        .filter(|(fp, e)| {
            **fp != protect
                && matches!(e.state, EntryState::Ready(_))
                && heap.is_none_or(|h| entry_on_heap(e, h))
        })
        .map(|(fp, e)| {
            let cost = entry_cost(*fp, e, inner.tick, feed);
            (keep_score(&cost, cfg.decay_ticks), e.last_used, *fp)
        })
        .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)))
        .map(|(_, _, fp)| fp)
}

/// Move a hot victim to the cold tier: its simulated-heap cohorts are
/// released (spilled bytes relieve the heap — that is the point of
/// spilling), its bytes migrate from the owning tenant's live-cache
/// counter to its spill counter, and the cold tier makes room by
/// dropping its own lowest-value entries first (each cold drop is a
/// `spill_evictions` and marks the fingerprint for remat accounting).
fn spill_entry(inner: &mut CacheInner, fp: Fingerprint, cfg: &CacheConfig, obs: Option<&CacheObs>) {
    if !matches!(
        inner.entries.get(&fp),
        Some(Entry {
            state: EntryState::Ready(_),
            ..
        })
    ) {
        return;
    }
    let e = inner.entries.remove(&fp).expect("presence checked above");
    let EntryState::Ready(value) = e.state else {
        unreachable!("readiness checked above")
    };
    inner.stats.bytes_cached = inner.stats.bytes_cached.saturating_sub(e.bytes);
    inner.stats.entries = inner.stats.entries.saturating_sub(1);
    if let Some(t) = &e.tenant {
        t.counters()
            .cache_live_bytes
            .fetch_sub(e.bytes, Ordering::Relaxed);
        t.counters()
            .cache_spill_bytes
            .fetch_add(e.bytes, Ordering::Relaxed);
    }
    for (heap, cohort) in &e.cohorts {
        heap.release_cohort(*cohort);
    }
    // Make room in the cold tier. `decide` only spills entries that fit
    // the tier's capacity, so this never needs to touch the incoming
    // entry itself.
    while inner.spill.bytes + e.bytes > cfg.spill_bytes {
        match inner.spill.victim(inner.tick, cfg.decay_ticks) {
            Some(victim) => {
                if let Some(items) = release_spilled(inner, victim) {
                    inner.dropped.insert(victim, items);
                    inner.stats.spill_evictions += 1;
                }
            }
            None => break,
        }
    }
    inner.spill.insert(
        fp,
        SpillEntry {
            value,
            bytes: e.bytes,
            items: e.items,
            recompute_secs: e.recompute_secs,
            last_used: e.last_used,
            seen: e.seen,
            tenant: e.tenant,
        },
    );
    inner.stats.spills += 1;
    inner.stats.decisions_spill += 1;
    inner.stats.bytes_spilled += e.bytes;
    inner.stats.spill_entries += 1;
    if let Some(o) = obs {
        o.obs.tracer.instant(SpanKind::CacheSpill, e.bytes, e.items);
    }
}

/// Execute the tier heuristic on a chosen victim: spill it or drop it.
/// Either way the entry leaves the hot tier — only its fate differs.
fn evict_one(
    inner: &mut CacheInner,
    fp: Fingerprint,
    cfg: &CacheConfig,
    feed: Option<&StatsStore>,
    obs: Option<&CacheObs>,
) {
    let cost = match inner.entries.get(&fp) {
        Some(e) => entry_cost(fp, e, inner.tick, feed),
        None => return,
    };
    if cost.stats_fed {
        inner.stats.stats_fed_decisions += 1;
    }
    match decide(&cost, cfg) {
        TierDecision::Spill => spill_entry(inner, fp, cfg, obs),
        _ => {
            if let Some(e) = inner.entries.get(&fp) {
                inner.dropped.insert(fp, e.items);
            }
            release_entry(inner, fp);
            inner.stats.decisions_drop += 1;
        }
    }
}

/// Age out cold-tier entries whose staleness-decayed recompute value no
/// longer beats their reload cost — the same comparison
/// [`tier::decide`] made when it spilled them, re-evaluated at the
/// current LRU tick. An entry that was worth spilling while warm stops
/// paying for its tier bytes once it has gone unread long enough;
/// dropping it then is exactly what `decide` would do today. Runs at
/// the head of every eviction pass. Aged-out fingerprints are marked
/// for rematerialization accounting, counted in
/// [`CacheStats::decisions_aged_out`], and emit a `cache.age_out`
/// trace event each.
fn age_out_spill(
    inner: &mut CacheInner,
    cfg: &CacheConfig,
    feed: Option<&StatsStore>,
    obs: Option<&CacheObs>,
) {
    if cfg.decay_ticks == 0 || inner.spill.entries.is_empty() {
        return;
    }
    let now = inner.tick;
    let stale: Vec<(Fingerprint, u64)> = inner
        .spill
        .entries
        .iter()
        .filter(|(fp, s)| {
            // Protect by the worst observed materialization, exactly as
            // the keep/spill/drop heuristic did when it spilled this
            // entry (see `entry_cost`).
            let mut recompute_secs = s.recompute_secs;
            if let Some(store) = feed {
                if let Some(pc) = store.prefix_cost(fp.0) {
                    if pc.samples > 0 {
                        recompute_secs = recompute_secs.max(pc.peak_secs);
                    }
                }
            }
            let age = now.saturating_sub(s.last_used);
            let reload_secs = s.bytes as f64 * cfg.reload_secs_per_byte;
            tier::decay(age, cfg.decay_ticks) * recompute_secs < reload_secs
        })
        .map(|(fp, s)| (*fp, s.bytes))
        .collect();
    for (fp, bytes) in stale {
        if let Some(items) = release_spilled(inner, fp) {
            inner.dropped.insert(fp, items);
            inner.stats.decisions_aged_out += 1;
            if let Some(o) = obs {
                o.obs.tracer.instant(SpanKind::CacheAgeOut, bytes, items);
            }
        }
    }
}

/// The eviction pass run after every insert (and reload promotion). Two
/// triggers:
///
/// * **capacity** — hot-tier bytes above [`CacheConfig::max_bytes`]:
///   evict (any heap) until back under the cap;
/// * **heap pressure** — the producing heap's occupancy at or above
///   `watermark × total_bytes`: release half the bytes cached *on that
///   heap*, giving its next minor/major collection real garbage to
///   reclaim (entries charged to other heaps are left alone — evicting
///   them would destroy warm state without relieving anything).
///
/// Each victim then goes through the keep/spill/drop heuristic
/// ([`evict_one`]); survivors of a triggered pass count as keep
/// decisions. Returns the number of hot-tier departures.
fn evict_under_pressure(
    inner: &mut CacheInner,
    protect: Fingerprint,
    heap: &Arc<SimHeap>,
    cfg: &CacheConfig,
    feed: Option<&StatsStore>,
    obs: Option<&CacheObs>,
) -> u64 {
    age_out_spill(inner, cfg, feed, obs);
    let mut evicted = 0u64;
    let mut triggered = false;
    while inner.stats.bytes_cached > cfg.max_bytes {
        triggered = true;
        match pick_victim(inner, protect, None, cfg, feed) {
            Some(fp) => {
                evict_one(inner, fp, cfg, feed, obs);
                evicted += 1;
            }
            None => break,
        }
    }
    let pressure = heap.enabled()
        && (heap.heap_used() as f64) >= cfg.watermark * heap.params().total_bytes as f64;
    if pressure {
        triggered = true;
        let on_heap = |inner: &CacheInner| -> u64 {
            inner
                .entries
                .values()
                .filter(|e| entry_on_heap(e, heap))
                .map(|e| e.bytes)
                .sum()
        };
        let target = on_heap(inner) / 2;
        while on_heap(inner) > target {
            match pick_victim(inner, protect, Some(heap), cfg, feed) {
                Some(fp) => {
                    evict_one(inner, fp, cfg, feed, obs);
                    evicted += 1;
                }
                None => break,
            }
        }
    }
    if triggered {
        // Survivors were examined and retained — explicit keep
        // decisions, so the keep/spill/drop mix is observable.
        inner.stats.decisions_keep += inner
            .entries
            .values()
            .filter(|e| matches!(e.state, EntryState::Ready(_)))
            .count() as u64;
    }
    inner.stats.evictions += evicted;
    evicted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::HeapParams;

    fn store(v: Vec<Vec<i64>>) -> Stored {
        Arc::new(v)
    }

    fn cfg() -> CacheConfig {
        CacheConfig::default()
    }

    fn claim(cache: &MaterializationCache, fp: Fingerprint) -> Ticket<'_> {
        match cache.begin(fp) {
            Begin::Claimed(t) => t,
            _ => panic!("expected a claim for {fp}"),
        }
    }

    #[test]
    fn identity_ordinals_are_first_seen_order() {
        let cache = MaterializationCache::new();
        assert_eq!(cache.identity_ordinal(0xAAAA), 0);
        assert_eq!(cache.identity_ordinal(0xBBBB), 1);
        assert_eq!(cache.identity_ordinal(0xAAAA), 0, "stable on re-registration");
    }

    #[test]
    fn miss_store_hit_roundtrip() {
        let cache = MaterializationCache::new();
        let heap = SimHeap::disabled();
        let fp = Fingerprint(42);
        let ticket = claim(&cache, fp);
        let v = store(vec![vec![1, 2], vec![3]]);
        cache.complete(ticket, v, 96, 3, 0.01, None, &heap, &cfg(), None);
        match cache.begin(fp) {
            Begin::Ready { value, waited, .. } => {
                assert!(!waited);
                // The caller confirms the read after its typed downcast
                // succeeds (see `CacheStage::execute`).
                cache.record_read(waited);
                let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                assert_eq!(*shards, vec![vec![1, 2], vec![3]]);
            }
            _ => panic!("stored entry must hit"),
        }
        let s = cache.stats();
        assert_eq!((s.misses, s.hits, s.entries, s.bytes_cached), (1, 1, 1, 96));
        assert_eq!(s.type_conflicts, 0);
    }

    #[test]
    fn aborted_claim_recovers() {
        let cache = MaterializationCache::new();
        let fp = Fingerprint(7);
        drop(claim(&cache, fp)); // claimant "panicked"
        // The fingerprint is claimable again, not deadlocked in-flight.
        let t = claim(&cache, fp);
        let v = store(vec![vec![1]]);
        cache.complete(t, v, 16, 1, 0.0, None, &SimHeap::disabled(), &cfg(), None);
        assert!(cache.contains(fp));
    }

    #[test]
    fn waiters_share_one_in_flight_computation() {
        let cache = Arc::new(MaterializationCache::new());
        let heap = SimHeap::disabled();
        let fp = Fingerprint(9);
        let ticket = claim(&cache, fp);
        let waiter = {
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || match cache.begin(fp) {
                Begin::Ready { value, waited, .. } => {
                    cache.record_read(waited);
                    let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                    (shards.len(), waited)
                }
                _ => panic!("waiter must not recompute"),
            })
        };
        // Give the waiter time to block on the in-flight entry.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let v = store(vec![vec![5], vec![6]]);
        cache.complete(ticket, v, 32, 2, 0.0, None, &heap, &cfg(), None);
        let (shards, waited) = waiter.join().unwrap();
        assert_eq!(shards, 2);
        assert!(waited);
        let s = cache.stats();
        assert_eq!((s.misses, s.shared_in_flight, s.hits), (1, 1, 0));
    }

    #[test]
    fn type_conflicts_are_counted_not_served() {
        let cache = MaterializationCache::new();
        let fp = Fingerprint(77);
        let t = claim(&cache, fp);
        let v = store(vec![vec![1]]);
        cache.complete(t, v, 16, 1, 0.0, None, &SimHeap::disabled(), &cfg(), None);
        match cache.begin(fp) {
            Begin::Ready { value, .. } => {
                assert!(value.downcast::<Vec<Vec<String>>>().is_err());
                cache.record_type_conflict();
            }
            _ => panic!("stored entry must be found"),
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.type_conflicts), (0, 1));
    }

    #[test]
    fn capacity_eviction_is_lru_first() {
        let cache = MaterializationCache::new();
        let heap = SimHeap::disabled();
        let tight = CacheConfig {
            max_bytes: 100,
            ..CacheConfig::default()
        };
        let (a, b, c) = (Fingerprint(1), Fingerprint(2), Fingerprint(3));
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 0.5, None, &heap, &tight, None);
        let t = claim(&cache, b);
        cache.complete(t, store(vec![vec![2]]), 60, 1, 0.5, None, &heap, &tight, None);
        // Inserting B overflowed the cap: A (older) was evicted.
        assert!(!cache.contains(a));
        assert!(cache.contains(b));
        // Touch B, insert C: B is now most recent, but C is protected as
        // the fresh insert, so B survives only if the cap allows one —
        // it doesn't, and B is the only candidate.
        let _ = cache.begin(b);
        let t = claim(&cache, c);
        let v = store(vec![vec![3]]);
        let evicted = cache.complete(t, v, 60, 1, 0.5, None, &heap, &tight, None);
        assert_eq!(evicted, 1);
        assert!(!cache.contains(b));
        assert!(cache.contains(c));
        assert_eq!(cache.stats().evictions, 2);
    }

    #[test]
    fn heap_pressure_halves_cached_bytes() {
        // A tiny enabled heap filled past the watermark: the insert pass
        // must release cached cohorts back to it.
        let heap = SimHeap::new(HeapParams {
            total_bytes: 4 << 20,
            time_scale: 0.0,
            sample_every: 1e9,
            ..HeapParams::default()
        });
        let filler = heap.cohort("filler");
        let mut a = heap.thread_alloc();
        for _ in 0..3000 {
            a.alloc(filler, 1024); // ~3 MiB live of 4 MiB total
        }
        a.flush();
        let cache = MaterializationCache::new();
        let low = CacheConfig {
            watermark: 0.5,
            ..CacheConfig::default()
        };
        for i in 0..4 {
            let fp = Fingerprint(100 + i);
            let t = claim(&cache, fp);
            cache.complete(t, store(vec![vec![i as i64]]), 1000, 1, 0.1, None, &heap, &low, None);
        }
        let s = cache.stats();
        assert!(s.evictions > 0, "pressure must evict: {s:?}");
        assert!(s.bytes_cached < 4000, "cached bytes must shrink: {s:?}");
    }

    #[test]
    fn remove_and_clear_release_cohort_bytes() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let fp = Fingerprint(55);
        let t = claim(&cache, fp);
        cache.complete(t, store(vec![vec![1]]), 4096, 1, 0.0, None, &heap, &cfg(), None);
        assert_eq!(cache.stats().bytes_cached, 4096);
        assert!(cache.remove(fp));
        assert!(!cache.remove(fp), "second removal finds nothing");
        assert_eq!(cache.stats().bytes_cached, 0);
        let t = claim(&cache, fp);
        cache.complete(t, store(vec![vec![2]]), 64, 1, 0.0, None, &heap, &cfg(), None);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
        assert!(!cache.contains(fp));
    }

    #[test]
    fn delta_merge_extends_entry_and_cas_guards_races() {
        let cache = MaterializationCache::new();
        let heap = SimHeap::disabled();
        let fp = Fingerprint(91);
        let t = claim(&cache, fp);
        cache.complete(t, store(vec![vec![1, 2]]), 32, 2, 0.0, Some(2), &heap, &cfg(), None);
        let seen = match cache.begin(fp) {
            Begin::Ready { seen, waited, .. } => {
                cache.record_read(waited);
                seen
            }
            _ => panic!("entry must be ready"),
        };
        assert_eq!(seen, Some(2), "append mark surfaces to readers");
        let (merged, _) =
            cache.merge_delta(fp, 2, store(vec![vec![1, 2], vec![3]]), 16, 1, 3, &heap, &cfg());
        assert!(merged);
        // A straggler still holding the pre-merge mark loses the CAS.
        let (merged, _) = cache.merge_delta(fp, 2, store(vec![vec![9]]), 16, 1, 3, &heap, &cfg());
        assert!(!merged, "stale mark must not clobber the merged entry");
        let s = cache.stats();
        assert_eq!((s.delta_merges, s.delta_items, s.bytes_cached), (1, 1, 48));
        match cache.begin(fp) {
            Begin::Ready { value, seen, .. } => {
                assert_eq!(seen, Some(3), "mark advances with the merge");
                let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                assert_eq!(*shards, vec![vec![1, 2], vec![3]]);
            }
            _ => panic!("merged entry must stay ready"),
        }
    }

    /// A tight hot tier with a near-free reload: every eviction spills.
    fn tiered(max_bytes: u64) -> CacheConfig {
        CacheConfig {
            max_bytes,
            spill_bytes: 1 << 20,
            reload_secs_per_byte: 1e-12,
            ..CacheConfig::default()
        }
    }

    #[test]
    fn spill_reload_roundtrip_preserves_value_and_accounting() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let tight = tiered(100);
        let (a, b) = (Fingerprint(1), Fingerprint(2));
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1, 2]]), 60, 2, 0.5, None, &heap, &tight, None);
        let t = claim(&cache, b);
        cache.complete(t, store(vec![vec![9]]), 60, 1, 0.5, None, &heap, &tight, None);
        // A was evicted by capacity, but its recompute cost beat the
        // near-zero reload cost: it spilled instead of dropping.
        assert_eq!(cache.residency(a), Residency::Spilled);
        assert_eq!(cache.residency(b), Residency::Hot);
        let s = cache.stats();
        assert_eq!((s.evictions, s.spills, s.decisions_spill), (1, 1, 1));
        assert_eq!((s.bytes_spilled, s.spill_entries), (60, 1));
        let audit = cache.audit();
        assert_eq!(audit.hot_bytes, 60);
        assert_eq!(audit.cohort_bytes, 60, "spilled bytes left the heap");
        assert_eq!(audit.spill_bytes, 60);
        assert_eq!(audit.double_resident, 0);
        // Reading A serves it from the spill tier: digest-identical
        // value, promoted hot, reload traffic charged.
        match cache.begin(a) {
            Begin::Spilled {
                value,
                seen,
                bytes,
                items,
            } => {
                assert_eq!(seen, None);
                let shards = value.downcast::<Vec<Vec<i64>>>().unwrap();
                assert_eq!(*shards, vec![vec![1, 2]]);
                let (promoted, _) = cache.complete_reload(a, bytes, items, &heap, &tight);
                assert!(promoted);
            }
            _ => panic!("entry must be served from the spill tier"),
        }
        assert_eq!(cache.residency(a), Residency::Hot);
        let s = cache.stats();
        assert_eq!((s.reloads, s.reload_bytes), (1, 60));
        assert_eq!(s.hits, 0, "a reload is not a hot-tier hit");
        // Promoting A overflowed the cap again: B spilled in turn.
        assert_eq!(cache.residency(b), Residency::Spilled);
        assert_eq!(cache.audit().double_resident, 0);
    }

    #[test]
    fn cheap_entries_drop_and_remats_are_counted() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let dear_reload = CacheConfig {
            max_bytes: 100,
            spill_bytes: 1 << 20,
            reload_secs_per_byte: 1e9, // reloading is absurdly dear: never spill
            ..CacheConfig::default()
        };
        let (a, b) = (Fingerprint(1), Fingerprint(2));
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 1e-6, None, &heap, &dear_reload, None);
        let t = claim(&cache, b);
        cache.complete(t, store(vec![vec![2]]), 60, 1, 1e-6, None, &heap, &dear_reload, None);
        assert_eq!(cache.residency(a), Residency::Absent, "dropped, not spilled");
        let s = cache.stats();
        assert_eq!((s.evictions, s.spills, s.decisions_drop), (1, 0, 1));
        // Recomputing A goes through the claim path and counts as a
        // rematerialization that pressure caused.
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 1e-6, None, &heap, &dear_reload, None);
        let s = cache.stats();
        assert_eq!((s.rematerializations, s.remat_items), (1, 1));
    }

    #[test]
    fn spilled_entries_never_serve_cross_type_readers() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let tight = tiered(100);
        let (a, b) = (Fingerprint(1), Fingerprint(2));
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 0.5, None, &heap, &tight, None);
        let t = claim(&cache, b);
        cache.complete(t, store(vec![vec![2]]), 60, 1, 0.5, None, &heap, &tight, None);
        assert_eq!(cache.residency(a), Residency::Spilled);
        // A reader expecting a different element type must not be
        // served the spilled entry: the downcast fails, the reader
        // records the collision and recomputes (`CacheStage`
        // behaviour), and the entry stays where it was.
        match cache.begin(a) {
            Begin::Spilled { value, .. } => {
                assert!(value.downcast::<Vec<Vec<String>>>().is_err());
                cache.record_type_conflict();
            }
            _ => panic!("entry must be found in the spill tier"),
        }
        assert_eq!(cache.residency(a), Residency::Spilled, "a conflict must not promote");
        let s = cache.stats();
        assert_eq!((s.type_conflicts, s.reloads), (1, 0));
    }

    #[test]
    fn eviction_never_victimizes_an_in_flight_claim() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let tight = tiered(50);
        let claimed = Fingerprint(7);
        let ticket = claim(&cache, claimed);
        // Inserting over the cap triggers a pass while the claim is
        // pending; only ready entries are candidates.
        let t = claim(&cache, Fingerprint(8));
        cache.complete(t, store(vec![vec![1]]), 60, 1, 0.5, None, &heap, &tight, None);
        assert_eq!(cache.residency(claimed), Residency::InFlight);
        cache.complete(ticket, store(vec![vec![2]]), 60, 1, 0.5, None, &heap, &tight, None);
        assert!(cache.contains(claimed));
    }

    #[test]
    fn cold_tier_overflow_drops_lowest_value_spills() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let tight = CacheConfig {
            max_bytes: 100,
            spill_bytes: 100, // holds one 60 B spill, not two
            reload_secs_per_byte: 1e-12,
            ..CacheConfig::default()
        };
        for i in 0..3u64 {
            let t = claim(&cache, Fingerprint(i));
            cache.complete(t, store(vec![vec![i as i64]]), 60, 1, 0.5, None, &heap, &tight, None);
        }
        let s = cache.stats();
        assert_eq!(s.spills, 2, "two hot victims spilled");
        assert_eq!(s.spill_evictions, 1, "the older spill was dropped for the newer");
        assert_eq!((s.bytes_spilled, s.spill_entries), (60, 1));
        assert_eq!(cache.residency(Fingerprint(0)), Residency::Absent);
        assert_eq!(cache.residency(Fingerprint(1)), Residency::Spilled);
    }

    #[test]
    fn stale_spill_ages_out_once_decayed_value_falls_below_reload() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let cfg = CacheConfig {
            max_bytes: 100,
            spill_bytes: 1 << 20,
            reload_secs_per_byte: 1e-6, // 60 B → 60 µs reload
            decay_ticks: 4,
            ..CacheConfig::default()
        };
        let (a, b) = (Fingerprint(1), Fingerprint(2));
        // 1 ms recompute > 60 µs reload → pressure spills A, keeps it.
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 1e-3, None, &heap, &cfg, None);
        let t = claim(&cache, b);
        cache.complete(t, store(vec![vec![2]]), 60, 1, 1e-3, None, &heap, &cfg, None);
        assert_eq!(cache.residency(a), Residency::Spilled);
        assert_eq!(cache.stats().decisions_aged_out, 0);
        // A goes unread for ~5 half-lives while B stays warm: its
        // decayed value (1 ms × 0.5^(20/4) ≈ 31 µs) falls below the
        // 60 µs reload cost.
        for _ in 0..20 {
            match cache.begin(b) {
                Begin::Ready { value, waited, .. } => {
                    drop(value);
                    cache.record_read(waited);
                }
                _ => panic!("B must stay hot"),
            }
        }
        // The next eviction pass opens with the age-out sweep.
        let t = claim(&cache, Fingerprint(3));
        cache.complete(t, store(vec![vec![3]]), 60, 1, 1e-3, None, &heap, &cfg, None);
        assert_eq!(cache.residency(a), Residency::Absent, "stale spill aged out");
        let s = cache.stats();
        assert_eq!(s.decisions_aged_out, 1);
        assert_eq!(s.spill_evictions, 0, "aging out is not a capacity drop");
        // Recomputing the aged-out prefix counts as a rematerialization
        // — the cost the sweep judged cheaper than holding the bytes.
        let t = claim(&cache, a);
        cache.complete(t, store(vec![vec![1]]), 60, 1, 1e-3, None, &heap, &cfg, None);
        assert!(cache.stats().rematerializations >= 1);
    }

    #[test]
    fn cost_feed_turns_a_drop_into_a_spill() {
        let heap = SimHeap::new(HeapParams::no_injection());
        let cache = MaterializationCache::new();
        let stats = Arc::new(StatsStore::new());
        // The store observed this prefix taking real time to compute,
        // even though the cache's own stopwatch saw almost nothing.
        stats.record_prefix_cost(1, 2.0, 60);
        cache.attach_cost_feed(Arc::clone(&stats));
        let cfg = CacheConfig {
            max_bytes: 100,
            spill_bytes: 1 << 20,
            reload_secs_per_byte: 1e-3, // reload costs 0.06 s for 60 B
            ..CacheConfig::default()
        };
        let t = claim(&cache, Fingerprint(1));
        cache.complete(t, store(vec![vec![1]]), 60, 1, 1e-9, None, &heap, &cfg, None);
        let t = claim(&cache, Fingerprint(2));
        cache.complete(t, store(vec![vec![2]]), 60, 1, 1e-9, None, &heap, &cfg, None);
        let s = cache.stats();
        assert!(s.stats_fed_decisions >= 1, "{s:?}");
        // On the stopwatch alone (1 ns ≪ 60 ms reload) the victim would
        // have dropped; the observed 2 s recompute made it spill.
        assert_eq!(cache.residency(Fingerprint(1)), Residency::Spilled);
        assert!(s.decisions_keep >= 1, "the survivor counts as a keep: {s:?}");
    }

    /// Satellite: seeded random insert/read/pressure sequences uphold
    /// the tier invariants — no double residency, counters match the
    /// ground truth (including live cohort bytes), in-flight claims
    /// survive every pass, and served values (hot, spilled, or
    /// reloaded) are byte-identical to what was stored.
    #[test]
    fn tier_invariants_hold_under_random_op_sequences() {
        use crate::testkit::prop::{assert_prop_shrink, shrink_vec, usize_in, vec_of, Gen};

        const KEYS: u64 = 6;
        #[derive(Clone, Debug)]
        enum Op {
            Insert(u64),
            Read(u64),
            Remove(u64),
            Claim(u64),
            Abort(u64),
        }

        fn payload(key: u64) -> Vec<Vec<i64>> {
            vec![vec![key as i64, key as i64 + 1], vec![-(key as i64)]]
        }
        fn bytes_of(key: u64) -> u64 {
            64 + key * 8
        }
        // Even keys are trivially cheap (pressure drops them), odd keys
        // are expensive (pressure spills them) — both heuristic arms
        // run in every long sequence.
        fn secs_of(key: u64) -> f64 {
            if key % 2 == 0 {
                1e-12
            } else {
                0.5
            }
        }

        let ops = vec_of(
            Gen::new(|rng, _| {
                let key = rng.below(KEYS);
                match rng.below(10) {
                    0 => Op::Remove(key),
                    1 => Op::Claim(key),
                    2 => Op::Abort(key),
                    3 | 4 | 5 => Op::Insert(key),
                    _ => Op::Read(key),
                }
            }),
            40,
        );

        assert_prop_shrink("cache tier invariants", &ops, |v| shrink_vec(v), |ops| {
            let cfg = CacheConfig {
                max_bytes: 150,
                spill_bytes: 220,
                reload_secs_per_byte: 1e-6,
                ..CacheConfig::default()
            };
            let heap = SimHeap::new(HeapParams::no_injection());
            let cache = MaterializationCache::new();
            let mut claims: HashMap<u64, Ticket<'_>> = HashMap::new();
            let served = |value: &Stored, key: u64| -> Result<(), String> {
                let shards = Arc::clone(value)
                    .downcast::<Vec<Vec<i64>>>()
                    .map_err(|_| format!("key {key}: stored type mismatch"))?;
                if *shards != payload(key) {
                    return Err(format!("key {key}: served value diverged: {shards:?}"));
                }
                Ok(())
            };
            for op in ops {
                match op {
                    Op::Insert(k) | Op::Read(k) if claims.contains_key(k) => {
                        // `begin` on a fingerprint we hold in-flight
                        // would deadlock; complete the claim instead.
                        let ticket = claims.remove(k).unwrap();
                        let v: Stored = Arc::new(payload(*k));
                        cache.complete(
                            ticket, v, bytes_of(*k), 3, secs_of(*k), None, &heap, &cfg, None,
                        );
                    }
                    Op::Insert(k) | Op::Read(k) => match cache.begin(Fingerprint(*k)) {
                        Begin::Ready { value, waited, .. } => {
                            served(&value, *k)?;
                            cache.record_read(waited);
                        }
                        Begin::Spilled {
                            value,
                            bytes,
                            items,
                            ..
                        } => {
                            served(&value, *k)?;
                            cache.complete_reload(Fingerprint(*k), bytes, items, &heap, &cfg);
                        }
                        Begin::Claimed(ticket) => {
                            let v: Stored = Arc::new(payload(*k));
                            cache.complete(
                                ticket, v, bytes_of(*k), 3, secs_of(*k), None, &heap, &cfg, None,
                            );
                        }
                    },
                    Op::Claim(k) => {
                        if !claims.contains_key(k) {
                            if let Begin::Claimed(t) = cache.begin(Fingerprint(*k)) {
                                claims.insert(*k, t);
                            }
                        }
                    }
                    Op::Abort(k) => {
                        claims.remove(k);
                    }
                    Op::Remove(k) => {
                        if !claims.contains_key(k) {
                            cache.remove(Fingerprint(*k));
                        }
                    }
                }
                // Invariants after every op.
                let a = cache.audit();
                let s = cache.stats();
                if a.double_resident != 0 {
                    return Err(format!("double residency: {a:?}"));
                }
                if a.hot_bytes != s.bytes_cached || a.hot_entries != s.entries {
                    return Err(format!("hot accounting drifted: {a:?} vs {s:?}"));
                }
                if a.spill_bytes != s.bytes_spilled || a.spill_entries != s.spill_entries {
                    return Err(format!("spill accounting drifted: {a:?} vs {s:?}"));
                }
                if a.cohort_bytes != a.hot_bytes {
                    return Err(format!("cohort bytes diverged from hot bytes: {a:?}"));
                }
                if a.in_flight != claims.len() {
                    return Err(format!(
                        "{} claims held but {} in flight — a pass victimized a claim",
                        claims.len(),
                        a.in_flight
                    ));
                }
            }
            Ok(())
        });
    }
}
