//! Two-tier residency and the DTR-style keep/spill/drop heuristic.
//!
//! The paper's headline claim is that semantic information lets the
//! framework trade recomputation against memory pressure automatically
//! (§4: the combining optimizer's GC-pressure cut is where the 2.0×
//! comes from). The hot tier is the original PR 5 store — shard outputs
//! charged to `cache.entry` SimHeap cohorts. This module adds the cold
//! tier and the decision model: on pressure, each victim's *staleness-
//! decayed observed recompute cost* is weighed against its *reload
//! cost* (`bytes × reload_secs_per_byte`), echoing the
//! evict/rematerialize decision Dynamic Tensor Rematerialization makes
//! across a two-level memory. Expensive-to-recompute entries spill —
//! their heap cohorts are released, so spilled bytes genuinely relieve
//! simulated GC pressure — while cheap or stale entries drop.
//!
//! Recompute costs come from two sources, and the larger wins: the wall
//! time the cache itself measured when the entry materialized, and the
//! per-fingerprint observed compute time exported by the session's
//! [`StatsStore`](crate::stats::StatsStore) (the PR 8 feedback store),
//! so repeated materializations sharpen the estimate.

use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

use crate::api::config::CacheConfig;
use crate::cache::fingerprint::Fingerprint;
use crate::cache::Stored;
use crate::govern::TenantHandle;

/// Where a fingerprint currently lives in the two-tier store
/// (surfaced by [`MaterializationCache::residency`](crate::cache::MaterializationCache::residency)
/// and in `explain()` cut-point lines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Ready in the hot tier: a read is a hit, served at zero cost.
    Hot,
    /// A claim holder is materializing it right now; readers wait.
    InFlight,
    /// Resident in the cold spill tier: a read reloads it (simulated
    /// `bytes × reload_secs_per_byte` heap traffic) instead of
    /// recomputing the prefix.
    Spilled,
    /// Not cached anywhere: a read rematerializes through the claim
    /// path.
    Absent,
}

impl Residency {
    pub fn label(self) -> &'static str {
        match self {
            Residency::Hot => "hot",
            Residency::InFlight => "in-flight",
            Residency::Spilled => "spilled",
            Residency::Absent => "absent",
        }
    }
}

impl fmt::Display for Residency {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// What the heuristic chose for one entry under pressure. `Keep` never
/// applies to a chosen victim — the victim picker only offers entries
/// the pass must shrink past — but survivors of a triggered pass are
/// counted as explicit keep decisions in `CacheStats`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierDecision {
    Keep,
    Spill,
    Drop,
}

/// The inputs to one keep/spill/drop decision.
#[derive(Clone, Copy, Debug)]
pub(crate) struct EntryCost {
    /// Best recompute-cost estimate, seconds: the max of the wall time
    /// measured at materialization and the `StatsStore` per-prefix
    /// observed compute time (when a sample exists).
    pub recompute_secs: f64,
    /// Resident bytes (hot) or reload payload bytes (cold).
    pub bytes: u64,
    /// LRU ticks since the entry was last read.
    pub age: u64,
    /// Whether `recompute_secs` was informed by a `StatsStore` sample
    /// (vs. only the cache's own materialization stopwatch).
    pub stats_fed: bool,
}

/// Staleness multiplier: `0.5^(age / half_life)`. `half_life == 0`
/// disables decay (multiplier 1).
pub(crate) fn decay(age: u64, half_life: u64) -> f64 {
    if half_life == 0 {
        return 1.0;
    }
    0.5f64.powf(age as f64 / half_life as f64)
}

/// Value-per-byte of keeping an entry resident: decayed recompute cost
/// divided by the bytes it occupies. The victim picker evicts the
/// lowest score first — among equal costs, older entries score lower
/// (LRU order), and among equal ages, cheaper-to-recompute entries
/// score lower, preserving the pre-tiered ordering as the degenerate
/// case.
pub(crate) fn keep_score(cost: &EntryCost, half_life: u64) -> f64 {
    decay(cost.age, half_life) * cost.recompute_secs / cost.bytes.max(1) as f64
}

/// Decide a chosen victim's fate: spill when the decayed recompute cost
/// exceeds the simulated reload cost and the entry fits the cold tier,
/// otherwise drop. With `spill_bytes == 0` every eviction is a drop —
/// the pre-tiered LRU-drop baseline.
pub(crate) fn decide(cost: &EntryCost, cfg: &CacheConfig) -> TierDecision {
    if cfg.spill_bytes == 0 || cost.bytes > cfg.spill_bytes {
        return TierDecision::Drop;
    }
    let reload_secs = cost.bytes as f64 * cfg.reload_secs_per_byte;
    if decay(cost.age, cfg.decay_ticks) * cost.recompute_secs > reload_secs {
        TierDecision::Spill
    } else {
        TierDecision::Drop
    }
}

/// One cold-tier resident: the value survives (simulating a serialized
/// copy on spill storage) but its heap cohorts were released when it
/// left the hot tier, so it costs the simulated heap nothing until a
/// reload re-charges it.
pub(crate) struct SpillEntry {
    pub value: Stored,
    pub bytes: u64,
    pub items: u64,
    pub recompute_secs: f64,
    pub last_used: u64,
    pub seen: Option<u64>,
    pub tenant: Option<Arc<TenantHandle>>,
}

/// The cold tier. Lives inside `CacheInner` under the cache's single
/// mutex — no new lock ordering to reason about.
#[derive(Default)]
pub(crate) struct SpillStore {
    pub entries: HashMap<Fingerprint, SpillEntry>,
    /// Σ entry bytes — maintained by insert/take, checked by `audit()`.
    pub bytes: u64,
}

impl SpillStore {
    pub fn contains(&self, fp: &Fingerprint) -> bool {
        self.entries.contains_key(fp)
    }

    pub fn get_mut(&mut self, fp: &Fingerprint) -> Option<&mut SpillEntry> {
        self.entries.get_mut(fp)
    }

    pub fn insert(&mut self, fp: Fingerprint, entry: SpillEntry) {
        self.bytes += entry.bytes;
        if let Some(old) = self.entries.insert(fp, entry) {
            self.bytes = self.bytes.saturating_sub(old.bytes);
        }
    }

    pub fn take(&mut self, fp: &Fingerprint) -> Option<SpillEntry> {
        let e = self.entries.remove(fp)?;
        self.bytes = self.bytes.saturating_sub(e.bytes);
        Some(e)
    }

    /// The cold victim to drop when the tier itself is over capacity:
    /// lowest keep-score first, deterministic fingerprint tie-break.
    /// Ages are real — `now_tick` minus the entry's last read — so a
    /// long-unread spill decays toward zero value and is preferred over
    /// a recently-reloadable one even when its recompute cost was
    /// higher at spill time (decayed-value aging, not FIFO-cheapest).
    pub fn victim(&self, now_tick: u64, half_life: u64) -> Option<Fingerprint> {
        self.entries
            .iter()
            .map(|(fp, e)| {
                let cost = EntryCost {
                    recompute_secs: e.recompute_secs,
                    bytes: e.bytes,
                    age: now_tick.saturating_sub(e.last_used),
                    stats_fed: false,
                };
                (keep_score(&cost, half_life), e.last_used, *fp)
            })
            .min_by(|a, b| {
                a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
            })
            .map(|(_, _, fp)| fp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CacheConfig {
        CacheConfig {
            spill_bytes: 1 << 20,
            reload_secs_per_byte: 1e-6, // 1 s per MiB-ish: easy to straddle
            decay_ticks: 4,
            ..CacheConfig::default()
        }
    }

    fn cost(secs: f64, bytes: u64, age: u64) -> EntryCost {
        EntryCost { recompute_secs: secs, bytes, age, stats_fed: false }
    }

    #[test]
    fn decay_halves_per_half_life_and_zero_disables() {
        assert_eq!(decay(0, 4), 1.0);
        assert!((decay(4, 4) - 0.5).abs() < 1e-12);
        assert!((decay(8, 4) - 0.25).abs() < 1e-12);
        assert_eq!(decay(1_000_000, 0), 1.0);
    }

    #[test]
    fn expensive_recompute_spills_cheap_drops() {
        // 1000 B at 1 µs/B → reload costs 1 ms.
        assert_eq!(decide(&cost(1.0, 1000, 0), &cfg()), TierDecision::Spill);
        assert_eq!(decide(&cost(1e-6, 1000, 0), &cfg()), TierDecision::Drop);
    }

    #[test]
    fn staleness_decay_turns_spill_into_drop() {
        // Fresh: 4 ms recompute > 1 ms reload → spill. After 2 half
        // lives the decayed cost (1 ms) no longer beats the reload.
        let c = cfg();
        assert_eq!(decide(&cost(4e-3, 1000, 0), &c), TierDecision::Spill);
        assert_eq!(decide(&cost(4e-3, 1000, 8), &c), TierDecision::Drop);
    }

    #[test]
    fn disabled_or_oversized_spill_always_drops() {
        let mut c = cfg();
        c.spill_bytes = 0;
        assert_eq!(decide(&cost(100.0, 8, 0), &c), TierDecision::Drop);
        let mut c = cfg();
        c.spill_bytes = 100;
        assert_eq!(decide(&cost(100.0, 101, 0), &c), TierDecision::Drop);
    }

    #[test]
    fn keep_score_orders_lru_first_among_equals_then_cheapest() {
        // Equal cost and size: the older entry scores lower (goes
        // first) — the pre-tiered LRU ordering.
        let newer = keep_score(&cost(0.5, 60, 0), 32);
        let older = keep_score(&cost(0.5, 60, 5), 32);
        assert!(older < newer);
        // Equal age and size: cheaper-to-recompute scores lower.
        let cheap = keep_score(&cost(0.1, 60, 0), 32);
        let dear = keep_score(&cost(0.9, 60, 0), 32);
        assert!(cheap < dear);
        // Bigger entries score lower per byte at equal cost.
        assert!(keep_score(&cost(0.5, 600, 0), 32) < keep_score(&cost(0.5, 60, 0), 32));
    }

    #[test]
    fn spill_store_accounts_bytes_and_picks_cheapest_victim() {
        let mut s = SpillStore::default();
        let entry = |bytes, secs, used| SpillEntry {
            value: Arc::new(Vec::<Vec<i64>>::new()) as Stored,
            bytes,
            items: 1,
            recompute_secs: secs,
            last_used: used,
            seen: None,
            tenant: None,
        };
        s.insert(Fingerprint(1), entry(100, 0.5, 1));
        s.insert(Fingerprint(2), entry(100, 0.1, 2));
        s.insert(Fingerprint(3), entry(100, 0.5, 3));
        assert_eq!(s.bytes, 300);
        // Near-equal ages: cheapest recompute goes first.
        assert_eq!(s.victim(3, 32), Some(Fingerprint(2)));
        assert!(s.take(&Fingerprint(2)).is_some());
        assert_eq!(s.bytes, 200);
        // Equal costs: the older entry has decayed further and goes
        // first (the LRU ordering falls out of the decay term).
        assert_eq!(s.victim(3, 32), Some(Fingerprint(1)));
        assert!(s.take(&Fingerprint(9)).is_none());
        assert_eq!(s.bytes, 200);
    }

    #[test]
    fn victim_aging_outranks_recompute_cost() {
        let mut s = SpillStore::default();
        let entry = |bytes, secs, used| SpillEntry {
            value: Arc::new(Vec::<Vec<i64>>::new()) as Stored,
            bytes,
            items: 1,
            recompute_secs: secs,
            last_used: used,
            seen: None,
            tenant: None,
        };
        // An expensive spill nobody has read for ~25 half-lives versus
        // a recompute 5× cheaper read one tick ago: the decayed value
        // of the stale one is lower, so *it* is the victim — FIFO-
        // cheapest would have picked Fingerprint(2).
        s.insert(Fingerprint(1), entry(100, 0.5, 1));
        s.insert(Fingerprint(2), entry(100, 0.1, 99));
        assert_eq!(s.victim(100, 4), Some(Fingerprint(1)));
        // With decay disabled the raw cost ordering comes back.
        assert_eq!(s.victim(100, 0), Some(Fingerprint(2)));
    }
}
