//! Transformability analysis (paper §3.1.1 and §3.2 steps 2–5).
//!
//! Decides whether a reducer program can be rewritten into a combiner. The
//! two paper conditions:
//!
//! 1. *"the reducer iterates over all intermediate values"* — there is
//!    exactly one values-loop, with no early exit;
//! 2. *"the reduce operation is dependent only on the current intermediate
//!    value and current value in the iteration"* — PDG sources of every
//!    loop-body store ⊆ {accumulator locals, current value, constants}.
//!
//! Plus the two idioms handled directly: reducers that use only
//! `values.len()` (COUNT) or only `values[0]` (FIRST).

use super::pdg::{build_region, Source};
use super::rir::{Instr, Program};
use super::value::{Ty, Val};

/// How the reducer can be combined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Idiom {
    /// General fold: init / per-value combine / finalize slices.
    Fold,
    /// Uses only the size of the value list.
    Count,
    /// Uses only the first element of the value list.
    First,
}

/// Why a reducer cannot be transformed. Each variant is exercised by a
/// dedicated negative test — rejection is a feature, not an error path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reject {
    NoLoopNoIdiom,
    MultipleLoops,
    EarlyExit,
    EmitInLoop,
    ExternInInit,
    KeyInInit,
    BodyBadSource(String),
    StackCarriedIntoLoop,
    FinalBadSource(String),
    NoFinalEmit,
    MultipleFinalEmits,
    Malformed(String),
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::NoLoopNoIdiom => {
                write!(f, "no loop over the intermediate values and no recognized idiom")
            }
            Reject::MultipleLoops => write!(f, "more than one loop over the values"),
            Reject::EarlyExit => {
                write!(f, "early exit from the values loop (does not cover all values)")
            }
            Reject::EmitInLoop => write!(f, "emit inside the values loop (not a fold)"),
            Reject::ExternInInit => write!(f, "initialization has an external data dependency"),
            Reject::KeyInInit => write!(f, "initialization depends on the key"),
            Reject::BodyBadSource(src) => write!(f, "loop body depends on {src}"),
            Reject::StackCarriedIntoLoop => {
                write!(f, "loop body consumes stack values produced before the loop")
            }
            Reject::FinalBadSource(src) => write!(f, "finalization depends on {src}"),
            Reject::NoFinalEmit => write!(f, "no emit after the loop"),
            Reject::MultipleFinalEmits => write!(
                f,
                "multiple emits in finalization (only single-result reducers combine)"
            ),
            Reject::Malformed(msg) => write!(f, "malformed program: {msg}"),
        }
    }
}

impl std::error::Error for Reject {}

/// A successful analysis: the slice boundaries and inferred holder type.
#[derive(Clone, Debug, PartialEq)]
pub struct Analysis {
    pub idiom: Idiom,
    /// `[0, loop_start)` — becomes `initialize()`.
    pub init: (usize, usize),
    /// `(loop_start, loop_end)` exclusive of markers — becomes
    /// `combine(holder, v)`.
    pub body: (usize, usize),
    /// `(loop_end, emit]` — becomes `finalize(holder)`.
    pub fin: (usize, usize),
    /// Types of the holder locals after initialization (paper: "determine
    /// the holder type required").
    pub holder_ty: Vec<Ty>,
    /// Which locals the loop body actually updates (the accumulator set).
    pub acc_locals: Vec<u8>,
}

/// Cheap structural pre-check — the *detection* phase the agent times
/// separately (paper §4.3: 81 µs per class). True means "looks like a
/// reducer worth analyzing", not "transformable".
pub fn detect(prog: &Program) -> bool {
    prog.verify().is_ok()
        && prog
            .code
            .iter()
            .any(|i| matches!(i, Instr::IterStart | Instr::ValuesLen | Instr::ValuesFirst))
}

/// Full analysis — the *transformation* phase's front half.
pub fn analyze(prog: &Program) -> Result<Analysis, Reject> {
    prog.verify()
        .map_err(|e| Reject::Malformed(e.to_string()))?;

    let loops = prog
        .code
        .iter()
        .filter(|i| matches!(i, Instr::IterStart))
        .count();
    if loops > 1 {
        return Err(Reject::MultipleLoops);
    }
    if loops == 0 {
        return analyze_idiom(prog);
    }

    let (lo, hi) = prog.loop_span().expect("one loop exists");

    // Condition 1: the loop covers all values — no early exit.
    if prog.code[lo + 1..hi]
        .iter()
        .any(|i| matches!(i, Instr::BreakIf))
    {
        return Err(Reject::EarlyExit);
    }
    // A fold has exactly one emit, after the loop.
    if prog.code[lo + 1..hi]
        .iter()
        .any(|i| matches!(i, Instr::Emit))
    {
        return Err(Reject::EmitInLoop);
    }

    // --- Init slice checks (paper step 3) ---
    let init_pdg =
        build_region(prog, 0, lo).map_err(|e| Reject::Malformed(e.to_string()))?;
    for pc in 0..lo {
        if !matches!(prog.code[pc], Instr::Store(_)) {
            continue;
        }
        for s in init_pdg.sources(prog, pc) {
            match s {
                Source::Const => {}
                Source::Extern => return Err(Reject::ExternInInit),
                Source::Key => return Err(Reject::KeyInInit),
                // Values-dependent init (len/first/index) means the
                // "initialization" needs the materialized list — reject as
                // an external dependency on the collection.
                Source::Len | Source::First | Source::Index => {
                    return Err(Reject::ExternInInit)
                }
                Source::Cur => {
                    return Err(Reject::Malformed("LoadCur before loop".into()))
                }
                Source::LocalIn(_) => {
                    return Err(Reject::Malformed("read of undefined local in init".into()))
                }
            }
        }
    }

    // --- Body slice checks (paper step 4) ---
    let body_pdg =
        build_region(prog, lo + 1, hi).map_err(|e| Reject::Malformed(e.to_string()))?;
    let mut acc_locals: Vec<u8> = Vec::new();
    for pc in lo + 1..hi {
        let store_local = match prog.code[pc] {
            Instr::Store(l) => l,
            _ => continue,
        };
        if !acc_locals.contains(&store_local) {
            acc_locals.push(store_local);
        }
        for s in body_pdg.sources(prog, pc) {
            match s {
                Source::Const | Source::Cur | Source::LocalIn(_) => {}
                Source::Extern => return Err(Reject::BodyBadSource("an external value".into())),
                Source::Key => return Err(Reject::BodyBadSource("the key".into())),
                Source::Len => {
                    return Err(Reject::BodyBadSource("the value-list length".into()))
                }
                Source::First | Source::Index => {
                    return Err(Reject::BodyBadSource("random value-list access".into()))
                }
            }
        }
    }
    // The body must be stack-self-contained: simulate depth over the body;
    // it must never pop below its entry depth and must return to it.
    let mut depth = 0isize;
    for pc in lo + 1..hi {
        if let Some((pops, pushes)) = prog.code[pc].stack_effect() {
            depth -= pops as isize;
            if depth < 0 {
                return Err(Reject::StackCarriedIntoLoop);
            }
            depth += pushes as isize;
        }
    }
    if depth != 0 {
        return Err(Reject::StackCarriedIntoLoop);
    }

    // --- Final slice checks (paper step 5) ---
    let fin_lo = hi + 1;
    let fin_hi = prog.code.len();
    let emits: Vec<usize> = (fin_lo..fin_hi)
        .filter(|&pc| matches!(prog.code[pc], Instr::Emit))
        .collect();
    if emits.is_empty() {
        return Err(Reject::NoFinalEmit);
    }
    if emits.len() > 1 {
        return Err(Reject::MultipleFinalEmits);
    }
    let fin_pdg =
        build_region(prog, fin_lo, fin_hi).map_err(|e| Reject::Malformed(e.to_string()))?;
    for s in fin_pdg.sources(prog, emits[0]) {
        match s {
            Source::Const | Source::LocalIn(_) | Source::Key => {}
            Source::Extern => return Err(Reject::FinalBadSource("an external value".into())),
            Source::Cur => return Err(Reject::Malformed("LoadCur after loop".into())),
            Source::Len | Source::First | Source::Index => {
                return Err(Reject::FinalBadSource("the value list".into()))
            }
        }
    }

    // Holder type inference: execute the init slice abstractly (it is
    // constant-only, so concrete execution is exact).
    let holder_ty = infer_holder(prog, lo)?;

    Ok(Analysis {
        idiom: Idiom::Fold,
        init: (0, lo),
        body: (lo + 1, hi),
        fin: (fin_lo, fin_hi),
        holder_ty,
        acc_locals,
    })
}

/// Loop-free programs: COUNT / FIRST idioms.
fn analyze_idiom(prog: &Program) -> Result<Analysis, Reject> {
    let uses = |pred: fn(&Instr) -> bool| prog.code.iter().any(pred);
    let uses_len = uses(|i| matches!(i, Instr::ValuesLen));
    let uses_first = uses(|i| matches!(i, Instr::ValuesFirst));
    let uses_index = uses(|i| matches!(i, Instr::ValuesIndex));
    let uses_extern = uses(|i| matches!(i, Instr::LoadExtern(_)));
    if uses_extern || uses_index || (uses_len && uses_first) {
        return Err(Reject::NoLoopNoIdiom);
    }
    let emits = prog.code.iter().filter(|i| matches!(i, Instr::Emit)).count();
    if emits != 1 {
        return Err(Reject::MultipleFinalEmits);
    }
    let idiom = if uses_len {
        Idiom::Count
    } else if uses_first {
        Idiom::First
    } else {
        return Err(Reject::NoLoopNoIdiom);
    };
    Ok(Analysis {
        idiom,
        init: (0, 0),
        body: (0, 0),
        fin: (0, prog.code.len()),
        holder_ty: vec![if idiom == Idiom::Count { Ty::I64 } else { Ty::Nil }],
        acc_locals: Vec::new(),
    })
}

/// Concretely run the constant-only init slice to learn each local's type.
fn infer_holder(prog: &Program, lo: usize) -> Result<Vec<Ty>, Reject> {
    use super::interp::{run_slice, ReduceCtx};
    let key = Val::Nil;
    let ctx = ReduceCtx::new(&key, &[]);
    let mut locals = vec![Val::Nil; prog.n_locals as usize];
    run_slice(prog, 0, lo, &mut locals, None, &ctx)
        .map_err(|e| Reject::Malformed(format!("init slice failed: {e}")))?;
    Ok(locals.iter().map(|v| v.ty()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::builder::{canon, ProgramBuilder};

    #[test]
    fn sum_is_a_fold() {
        let a = analyze(&canon::sum_i64("s")).unwrap();
        assert_eq!(a.idiom, Idiom::Fold);
        assert_eq!(a.holder_ty, vec![Ty::I64]);
        assert_eq!(a.acc_locals, vec![0]);
    }

    #[test]
    fn vec_sum_holder_type() {
        let a = analyze(&canon::sum_vec("v", 3)).unwrap();
        assert_eq!(a.holder_ty, vec![Ty::F64Vec]);
    }

    #[test]
    fn scaled_sum_has_nontrivial_finalize() {
        let a = analyze(&canon::scaled_sum_f64("ss", 2.0)).unwrap();
        assert_eq!(a.idiom, Idiom::Fold);
        assert!(a.fin.1 - a.fin.0 > 2, "finalize slice includes the scale");
    }

    #[test]
    fn count_idiom_detected() {
        let a = analyze(&canon::count("c")).unwrap();
        assert_eq!(a.idiom, Idiom::Count);
    }

    #[test]
    fn first_idiom_detected() {
        let a = analyze(&canon::first("f")).unwrap();
        assert_eq!(a.idiom, Idiom::First);
    }

    #[test]
    fn early_exit_rejected() {
        assert_eq!(analyze(&canon::early_exit("e")), Err(Reject::EarlyExit));
    }

    #[test]
    fn extern_init_rejected() {
        assert_eq!(analyze(&canon::extern_seed("x")), Err(Reject::ExternInInit));
    }

    #[test]
    fn random_access_rejected() {
        assert_eq!(analyze(&canon::random_access("r")), Err(Reject::NoLoopNoIdiom));
    }

    #[test]
    fn emit_in_loop_rejected() {
        assert_eq!(analyze(&canon::emit_in_loop("e")), Err(Reject::EmitInLoop));
    }

    #[test]
    fn extern_in_body_rejected() {
        let p = ProgramBuilder::new("b")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .load_extern(0)
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        assert!(matches!(analyze(&p), Err(Reject::BodyBadSource(_))));
    }

    #[test]
    fn len_in_body_rejected() {
        let p = ProgramBuilder::new("b")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .values_len()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        assert!(matches!(analyze(&p), Err(Reject::BodyBadSource(s)) if s.contains("length")));
    }

    #[test]
    fn key_dependent_init_rejected() {
        let p = ProgramBuilder::new("k")
            .load_key()
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        assert_eq!(analyze(&p), Err(Reject::KeyInInit));
    }

    #[test]
    fn key_in_finalize_allowed() {
        // Emitting something key-derived in finalization is fine — the key
        // is available at finalize time.
        let p = ProgramBuilder::new("kf")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        assert!(analyze(&p).is_ok());
    }

    #[test]
    fn two_loops_rejected() {
        let p = ProgramBuilder::new("2l")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        assert_eq!(analyze(&p), Err(Reject::MultipleLoops));
    }

    #[test]
    fn multi_emit_finalize_rejected() {
        let p = ProgramBuilder::new("2e")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .load(0)
            .emit()
            .build()
            .unwrap();
        assert_eq!(analyze(&p), Err(Reject::MultipleFinalEmits));
    }

    #[test]
    fn detection_is_cheap_and_permissive() {
        assert!(detect(&canon::sum_i64("s")));
        assert!(detect(&canon::count("c")));
        assert!(detect(&canon::early_exit("e"))); // detected, later rejected
        let no_values = ProgramBuilder::new("nv").const_i64(1).emit().build().unwrap();
        assert!(!detect(&no_values));
    }

    #[test]
    fn multi_local_fold_accepted() {
        // Two accumulators (sum and count) — LR-style.
        let p = ProgramBuilder::new("sumcount")
            .const_f64(0.0)
            .store(0)
            .const_i64(0)
            .store(1)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .load(1)
            .const_i64(1)
            .add()
            .store(1)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        let a = analyze(&p).unwrap();
        assert_eq!(a.holder_ty, vec![Ty::F64, Ty::I64]);
        assert_eq!(a.acc_locals, vec![0, 1]);
    }
}
