//! RIR — the Reducer Intermediate Representation.
//!
//! The stand-in for Java bytecode: a stack machine with locals, an explicit
//! construct for iterating the intermediate value list, and an `Emit` call.
//! A reducer program has the shape the paper's Figure 4 decompiles:
//!
//! ```text
//! <init block>            ; set up accumulator locals
//! IterStart               ; for (V value : values) {
//!   <body block>          ;   accumulate from LoadCur
//! IterEnd                 ; }
//! <final block>           ; compute the result value
//! Emit                    ; emitter.emit(key, result)
//! ```
//!
//! The instruction set deliberately includes constructs the optimizer must
//! **reject** — `LoadExtern` (external data dependency), `ValuesIndex`
//! (random access), `BreakIf` (early exit → doesn't cover all values),
//! `Emit` inside the loop — so the analysis has real negative cases, not
//! just a happy path. See [`crate::optimizer::analyze`](mod@crate::optimizer::analyze).

use super::value::Val;

/// One RIR instruction.
#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// Push a constant.
    Const(Val),
    /// Push local `n`.
    Load(u8),
    /// Pop into local `n`.
    Store(u8),
    /// Push the current iteration value (valid only between
    /// `IterStart`/`IterEnd`).
    LoadCur,
    /// Push the key as a value (rare; makes the reducer key-dependent).
    LoadKey,
    /// Push `values.len()` as I64 — the COUNT idiom marker.
    ValuesLen,
    /// Push `values[0]` — the FIRST idiom marker.
    ValuesFirst,
    /// Push `values[i]` where `i` is popped — random access; never
    /// transformable.
    ValuesIndex,
    /// Push a value from the enclosing environment (simulates a captured
    /// field — an *external data dependency* the analyzer must reject in
    /// the init block per paper §3.2 step 3).
    LoadExtern(u8),
    /// Begin the loop over all intermediate values.
    IterStart,
    /// End of the loop body.
    IterEnd,
    /// Pop condition; if true, exit the loop early (kills the "covers all
    /// values" property; never transformable).
    BreakIf,
    // Arithmetic (pop rhs, pop lhs, push result).
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
    /// Pop two, push Bool(lhs < rhs).
    Lt,
    /// Pop cond(Bool), pop else-val, pop then-val, push selected.
    Select,
    // Stack shuffling.
    Dup,
    Pop,
    Swap,
    /// Pop the result value and emit `(key, value)`.
    Emit,
}

impl Instr {
    /// Instruction mnemonics (diagnostics / golden tests).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::Const(_) => "const",
            Instr::Load(_) => "load",
            Instr::Store(_) => "store",
            Instr::LoadCur => "load_cur",
            Instr::LoadKey => "load_key",
            Instr::ValuesLen => "values_len",
            Instr::ValuesFirst => "values_first",
            Instr::ValuesIndex => "values_index",
            Instr::LoadExtern(_) => "load_extern",
            Instr::IterStart => "iter_start",
            Instr::IterEnd => "iter_end",
            Instr::BreakIf => "break_if",
            Instr::Add => "add",
            Instr::Sub => "sub",
            Instr::Mul => "mul",
            Instr::Div => "div",
            Instr::Min => "min",
            Instr::Max => "max",
            Instr::Lt => "lt",
            Instr::Select => "select",
            Instr::Dup => "dup",
            Instr::Pop => "pop",
            Instr::Swap => "swap",
            Instr::Emit => "emit",
        }
    }

    /// (pops, pushes) stack effect; `None` for control markers.
    pub fn stack_effect(&self) -> Option<(usize, usize)> {
        Some(match self {
            Instr::Const(_)
            | Instr::Load(_)
            | Instr::LoadCur
            | Instr::LoadKey
            | Instr::ValuesLen
            | Instr::ValuesFirst
            | Instr::LoadExtern(_) => (0, 1),
            Instr::ValuesIndex => (1, 1),
            Instr::Store(_) | Instr::Pop | Instr::Emit | Instr::BreakIf => (1, 0),
            Instr::Add
            | Instr::Sub
            | Instr::Mul
            | Instr::Div
            | Instr::Min
            | Instr::Max
            | Instr::Lt => (2, 1),
            Instr::Select => (3, 1),
            Instr::Dup => (1, 2),
            Instr::Swap => (2, 2),
            Instr::IterStart | Instr::IterEnd => return None,
        })
    }
}

/// A verified RIR reducer program.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    /// "Class name" — the agent's cache key and the unit the paper reports
    /// per-class timings over.
    pub name: String,
    pub code: Vec<Instr>,
    pub n_locals: u8,
}

/// Structural validation errors (malformed programs are refused before
/// they reach the interpreter or the analyzer).
#[derive(Clone, Debug, PartialEq)]
pub enum VerifyError {
    BadLoopNesting(usize),
    CurOutsideLoop(usize),
    Underflow(usize),
    UnbalancedStack(usize),
    BadLocal(u8, u8),
    NoEmit,
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VerifyError::BadLoopNesting(pc) => {
                write!(f, "nested or unmatched loop construct at pc {pc}")
            }
            VerifyError::CurOutsideLoop(pc) => {
                write!(f, "LoadCur/BreakIf outside loop at pc {pc}")
            }
            VerifyError::Underflow(pc) => write!(f, "stack underflow at pc {pc}"),
            VerifyError::UnbalancedStack(n) => {
                write!(f, "program leaves {n} operands on the stack")
            }
            VerifyError::BadLocal(local, n) => {
                write!(f, "local {local} exceeds declared n_locals {n}")
            }
            VerifyError::NoEmit => write!(f, "program has no Emit"),
        }
    }
}

impl std::error::Error for VerifyError {}

impl Program {
    pub fn new(name: impl Into<String>, code: Vec<Instr>, n_locals: u8) -> Self {
        Program {
            name: name.into(),
            code,
            n_locals,
        }
    }

    /// Structural verification: loop well-formedness, stack balance, local
    /// indices in range, at least one Emit. (Semantic transformability is
    /// the analyzer's job; this is the "can it run at all" check.)
    pub fn verify(&self) -> Result<(), VerifyError> {
        let mut depth = 0usize; // current loop nesting
        let mut stack = 0usize;
        let mut emits = 0usize;
        for (pc, ins) in self.code.iter().enumerate() {
            match ins {
                Instr::IterStart => {
                    if depth != 0 {
                        return Err(VerifyError::BadLoopNesting(pc));
                    }
                    depth = 1;
                }
                Instr::IterEnd => {
                    if depth != 1 {
                        return Err(VerifyError::BadLoopNesting(pc));
                    }
                    // Loop body must be stack-neutral per iteration: the
                    // verifier requires the stack at IterEnd to match the
                    // stack at IterStart. We enforce balance by requiring
                    // zero net effect inside (tracked via markers below).
                    depth = 0;
                }
                Instr::LoadCur | Instr::BreakIf if depth == 0 => {
                    return Err(VerifyError::CurOutsideLoop(pc));
                }
                Instr::Load(n) | Instr::Store(n) if *n >= self.n_locals => {
                    return Err(VerifyError::BadLocal(*n, self.n_locals));
                }
                _ => {}
            }
            if let Some((pops, pushes)) = ins.stack_effect() {
                if stack < pops {
                    return Err(VerifyError::Underflow(pc));
                }
                stack = stack - pops + pushes;
            }
            if matches!(ins, Instr::Emit) {
                emits += 1;
            }
        }
        if depth != 0 {
            return Err(VerifyError::BadLoopNesting(self.code.len()));
        }
        if stack != 0 {
            return Err(VerifyError::UnbalancedStack(stack));
        }
        if emits == 0 {
            return Err(VerifyError::NoEmit);
        }
        Ok(())
    }

    /// Indices of the loop delimiters, if the program has a loop.
    pub fn loop_span(&self) -> Option<(usize, usize)> {
        let start = self.code.iter().position(|i| matches!(i, Instr::IterStart))?;
        let end = self.code.iter().position(|i| matches!(i, Instr::IterEnd))?;
        (start < end).then_some((start, end))
    }

    /// Pretty-print the program (diagnostics and DESIGN.md listings).
    pub fn disassemble(&self) -> String {
        let mut out = format!("; program `{}` ({} locals)\n", self.name, self.n_locals);
        let mut indent = 0usize;
        for (pc, ins) in self.code.iter().enumerate() {
            if matches!(ins, Instr::IterEnd) {
                indent = indent.saturating_sub(1);
            }
            let pad = "  ".repeat(indent + 1);
            let arg = match ins {
                Instr::Const(v) => format!(" {v:?}"),
                Instr::Load(n) | Instr::Store(n) | Instr::LoadExtern(n) => format!(" {n}"),
                _ => String::new(),
            };
            out.push_str(&format!("{pc:>3}:{pad}{}{arg}\n", ins.mnemonic()));
            if matches!(ins, Instr::IterStart) {
                indent += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::builder::ProgramBuilder;

    fn sum_program() -> Program {
        // local0 = 0; for v { local0 += v }; emit local0
        ProgramBuilder::new("sum")
            .const_val(Val::I64(0))
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build_unchecked()
    }

    #[test]
    fn well_formed_program_verifies() {
        sum_program().verify().unwrap();
    }

    #[test]
    fn unmatched_loop_rejected() {
        let p = Program::new("bad", vec![Instr::IterStart, Instr::Const(Val::I64(0)), Instr::Emit], 0);
        assert!(matches!(p.verify(), Err(VerifyError::BadLoopNesting(_))));
    }

    #[test]
    fn loadcur_outside_loop_rejected() {
        let p = Program::new("bad", vec![Instr::LoadCur, Instr::Emit], 0);
        assert!(matches!(p.verify(), Err(VerifyError::CurOutsideLoop(0))));
    }

    #[test]
    fn stack_underflow_rejected() {
        let p = Program::new("bad", vec![Instr::Add, Instr::Emit], 0);
        assert!(matches!(p.verify(), Err(VerifyError::Underflow(0))));
    }

    #[test]
    fn unbalanced_stack_rejected() {
        let p = Program::new(
            "bad",
            vec![Instr::Const(Val::I64(1)), Instr::Const(Val::I64(2)), Instr::Emit],
            0,
        );
        assert!(matches!(p.verify(), Err(VerifyError::UnbalancedStack(1))));
    }

    #[test]
    fn bad_local_rejected() {
        let p = Program::new("bad", vec![Instr::Load(3), Instr::Emit], 1);
        assert!(matches!(p.verify(), Err(VerifyError::BadLocal(3, 1))));
    }

    #[test]
    fn no_emit_rejected() {
        let p = Program::new("bad", vec![Instr::Const(Val::I64(1)), Instr::Pop], 0);
        assert_eq!(p.verify(), Err(VerifyError::NoEmit));
    }

    #[test]
    fn loop_span_found() {
        let p = sum_program();
        let (s, e) = p.loop_span().unwrap();
        assert!(s < e);
        assert_eq!(p.code[s], Instr::IterStart);
        assert_eq!(p.code[e], Instr::IterEnd);
    }

    #[test]
    fn disassembly_is_readable() {
        let d = sum_program().disassemble();
        assert!(d.contains("iter_start"));
        assert!(d.contains("load_cur"));
        assert!(d.contains("emit"));
    }
}
