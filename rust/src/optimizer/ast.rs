//! A declarative reducer language compiled to RIR.
//!
//! The paper's closing argument (§6) is that "if semantic information can
//! be passed from the application developer to the parallel framework and
//! the compiler, significant performance improvements can be achieved".
//! [`ReduceSpec`] is that idea one level up from RIR: the user states the
//! reducer as *expressions* — accumulator initializers, per-value update
//! rules, and a result expression — and the framework compiles them to an
//! RIR [`Program`]. By construction the compiled program has the
//! fold shape the optimizer's analysis accepts (single loop over all
//! values, accumulator-only dependencies), so the semantic declaration
//! *is* the optimization license: specs using only accumulators and `Cur`
//! always take the combining flow.
//!
//! Non-fold escapes (`ValuesLen`, `Extern`, `Key` in inits) are still
//! expressible, and compile to programs the analyzer correctly rejects —
//! the DSL does not launder unsound reducers into combiners.

use super::rir::{Instr, Program, VerifyError};
use super::value::Val;

/// Binary operators available in reducer expressions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

impl BinOp {
    fn instr(self) -> Instr {
        match self {
            BinOp::Add => Instr::Add,
            BinOp::Sub => Instr::Sub,
            BinOp::Mul => Instr::Mul,
            BinOp::Div => Instr::Div,
            BinOp::Min => Instr::Min,
            BinOp::Max => Instr::Max,
        }
    }
}

/// A reducer expression.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Literal.
    Const(Val),
    /// Accumulator `n`.
    Acc(u8),
    /// The current intermediate value (valid in update rules only).
    Cur,
    /// The reduce key (valid in the result expression only).
    Key,
    /// `values.len()` — forces the COUNT idiom / rejection path.
    ValuesLen,
    /// `values[0]` — forces the FIRST idiom / rejection path.
    ValuesFirst,
    /// Captured environment slot — an external data dependency.
    Extern(u8),
    /// Binary operation.
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn add(self, rhs: Expr) -> Expr {
        Expr::Bin(BinOp::Add, Box::new(self), Box::new(rhs))
    }
    pub fn sub(self, rhs: Expr) -> Expr {
        Expr::Bin(BinOp::Sub, Box::new(self), Box::new(rhs))
    }
    pub fn mul(self, rhs: Expr) -> Expr {
        Expr::Bin(BinOp::Mul, Box::new(self), Box::new(rhs))
    }
    pub fn div(self, rhs: Expr) -> Expr {
        Expr::Bin(BinOp::Div, Box::new(self), Box::new(rhs))
    }
    pub fn min(self, rhs: Expr) -> Expr {
        Expr::Bin(BinOp::Min, Box::new(self), Box::new(rhs))
    }
    pub fn max(self, rhs: Expr) -> Expr {
        Expr::Bin(BinOp::Max, Box::new(self), Box::new(rhs))
    }

    /// Emit postorder stack code for this expression.
    fn codegen(&self, out: &mut Vec<Instr>) {
        match self {
            Expr::Const(v) => out.push(Instr::Const(v.clone())),
            Expr::Acc(n) => out.push(Instr::Load(*n)),
            Expr::Cur => out.push(Instr::LoadCur),
            Expr::Key => out.push(Instr::LoadKey),
            Expr::ValuesLen => out.push(Instr::ValuesLen),
            Expr::ValuesFirst => out.push(Instr::ValuesFirst),
            Expr::Extern(n) => out.push(Instr::LoadExtern(*n)),
            Expr::Bin(op, l, r) => {
                l.codegen(out);
                r.codegen(out);
                out.push(op.instr());
            }
        }
    }

    /// Does the expression mention `Cur` anywhere?
    fn uses_cur(&self) -> bool {
        match self {
            Expr::Cur => true,
            Expr::Bin(_, l, r) => l.uses_cur() || r.uses_cur(),
            _ => false,
        }
    }
}

/// Convenience constructors.
pub fn lit_i64(x: i64) -> Expr {
    Expr::Const(Val::I64(x))
}
pub fn lit_f64(x: f64) -> Expr {
    Expr::Const(Val::F64(x))
}
pub fn lit_vec(v: Vec<f64>) -> Expr {
    Expr::Const(Val::F64Vec(v))
}
pub fn acc(n: u8) -> Expr {
    Expr::Acc(n)
}
pub fn cur() -> Expr {
    Expr::Cur
}

/// Compile-time errors for specs (beyond RIR structural verification).
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    UnknownAcc(u8, usize),
    CurOutsideUpdate,
    Verify(VerifyError),
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::UnknownAcc(acc, n) => write!(
                f,
                "update rule targets accumulator {acc} but only {n} are declared"
            ),
            SpecError::CurOutsideUpdate => write!(f, "`Cur` used outside an update rule"),
            SpecError::Verify(e) => write!(f, "compiled program failed verification: {e}"),
        }
    }
}

impl std::error::Error for SpecError {}

impl From<VerifyError> for SpecError {
    fn from(e: VerifyError) -> Self {
        SpecError::Verify(e)
    }
}

/// A declarative reducer: `init` accumulators, apply `update` rules per
/// value, emit `result`.
#[derive(Clone, Debug)]
pub struct ReduceSpec {
    pub name: String,
    /// `init[i]` initializes accumulator `i`.
    pub init: Vec<Expr>,
    /// Per-value rules, applied in order: `acc[target] = expr`.
    pub update: Vec<(u8, Expr)>,
    /// Emitted value (may reference accumulators, consts, `Key`,
    /// `ValuesLen`/`ValuesFirst` for idioms).
    pub result: Expr,
}

impl ReduceSpec {
    /// A fresh spec with no accumulators.
    pub fn new(name: impl Into<String>, result: Expr) -> Self {
        ReduceSpec {
            name: name.into(),
            init: Vec::new(),
            update: Vec::new(),
            result,
        }
    }

    /// Declare an accumulator; returns its expression handle.
    pub fn with_acc(mut self, init: Expr) -> Self {
        self.init.push(init);
        self
    }

    /// Add a per-value update rule.
    pub fn with_update(mut self, target: u8, expr: Expr) -> Self {
        self.update.push((target, expr));
        self
    }

    /// Compile to RIR. The emitted shape is exactly the fold skeleton the
    /// analyzer slices (init / loop body / finalize / emit).
    pub fn compile(&self) -> Result<Program, SpecError> {
        // Static checks with readable errors before codegen.
        for (t, _) in &self.update {
            if *t as usize >= self.init.len() {
                return Err(SpecError::UnknownAcc(*t, self.init.len()));
            }
        }
        for e in &self.init {
            if e.uses_cur() {
                return Err(SpecError::CurOutsideUpdate);
            }
        }
        if self.result.uses_cur() {
            return Err(SpecError::CurOutsideUpdate);
        }

        let mut code = Vec::new();
        for (i, e) in self.init.iter().enumerate() {
            e.codegen(&mut code);
            code.push(Instr::Store(i as u8));
        }
        if !self.update.is_empty() {
            code.push(Instr::IterStart);
            for (target, e) in &self.update {
                e.codegen(&mut code);
                code.push(Instr::Store(*target));
            }
            code.push(Instr::IterEnd);
        }
        self.result.codegen(&mut code);
        code.push(Instr::Emit);

        let program = Program::new(self.name.clone(), code, self.init.len() as u8);
        program.verify()?;
        Ok(program)
    }
}

/// Ready-made specs for common aggregations (the "standard library" a
/// framework would ship; each compiles to an optimizer-accepted fold).
pub mod specs {
    use super::*;

    /// Σ values (i64).
    pub fn sum_i64(name: &str) -> ReduceSpec {
        ReduceSpec::new(name, acc(0))
            .with_acc(lit_i64(0))
            .with_update(0, acc(0).add(cur()))
    }

    /// Arithmetic mean: sum and count accumulators, divide at finalize —
    /// the classic "combiner needs state" aggregation (K-Means' §4.1.3
    /// challenge, solved exactly as the paper describes: carry the state,
    /// normalize at the end).
    pub fn mean_f64(name: &str) -> ReduceSpec {
        ReduceSpec::new(name, acc(0).div(acc(1)))
            .with_acc(lit_f64(0.0))
            .with_acc(lit_f64(0.0))
            .with_update(0, acc(0).add(cur()))
            .with_update(1, acc(1).add(lit_f64(1.0)))
    }

    /// Range width: max − min in one pass.
    pub fn range_i64(name: &str) -> ReduceSpec {
        ReduceSpec::new(name, acc(1).sub(acc(0)))
            .with_acc(lit_i64(i64::MAX))
            .with_acc(lit_i64(i64::MIN))
            .with_update(0, acc(0).min(cur()))
            .with_update(1, acc(1).max(cur()))
    }

    /// Sum of squares (f64) — variance building block.
    pub fn sum_sq_f64(name: &str) -> ReduceSpec {
        ReduceSpec::new(name, acc(0))
            .with_acc(lit_f64(0.0))
            .with_update(0, acc(0).add(cur().mul(cur())))
    }
}

#[cfg(test)]
mod tests {
    use super::specs;
    use super::*;
    use crate::optimizer::agent::OptimizerAgent;
    use crate::optimizer::analyze::{analyze, Idiom};
    use crate::optimizer::interp::{run_reduce, ReduceCtx};

    fn run(program: &Program, values: &[Val]) -> Vec<Val> {
        let key = Val::Str("k".into());
        let ctx = ReduceCtx::new(&key, values);
        let mut out = Vec::new();
        run_reduce(program, &ctx, |v| out.push(v)).unwrap();
        out
    }

    fn i64s(xs: &[i64]) -> Vec<Val> {
        xs.iter().map(|&x| Val::I64(x)).collect()
    }

    #[test]
    fn sum_spec_compiles_and_optimizes() {
        let p = specs::sum_i64("ast.sum").compile().unwrap();
        assert_eq!(run(&p, &i64s(&[1, 2, 3])), vec![Val::I64(6)]);
        let a = analyze(&p).unwrap();
        assert_eq!(a.idiom, Idiom::Fold);
        // The DSL's sum compiles to the exact shape the fast path matches.
        let agent = OptimizerAgent::new();
        let c = agent.process(&p).combiner().cloned().unwrap();
        assert!(c.fast_path().is_some());
    }

    #[test]
    fn mean_spec_divides_at_finalize() {
        let p = specs::mean_f64("ast.mean").compile().unwrap();
        let vals: Vec<Val> = [2.0, 4.0, 9.0].iter().map(|&x| Val::F64(x)).collect();
        assert_eq!(run(&p, &vals), vec![Val::F64(5.0)]);
        // Two accumulators → transformable fold, no single-acc fast path.
        let agent = OptimizerAgent::new();
        let d = agent.process(&p);
        let c = d.combiner().expect("mean is a fold");
        assert!(c.fast_path().is_none());
        // Combiner path computes the same mean.
        let mut h = c.initialize();
        for v in &vals {
            c.combine(&mut h, v).unwrap();
        }
        assert_eq!(c.finalize(h, &Val::Nil).unwrap(), Val::F64(5.0));
    }

    #[test]
    fn range_spec_two_accumulators() {
        let p = specs::range_i64("ast.range").compile().unwrap();
        assert_eq!(run(&p, &i64s(&[5, -3, 9, 0])), vec![Val::I64(12)]);
        assert!(analyze(&p).is_ok());
    }

    #[test]
    fn sum_sq_nested_expression() {
        let p = specs::sum_sq_f64("ast.sumsq").compile().unwrap();
        let vals: Vec<Val> = [1.0, 2.0, 3.0].iter().map(|&x| Val::F64(x)).collect();
        assert_eq!(run(&p, &vals), vec![Val::F64(14.0)]);
    }

    #[test]
    fn key_in_result_is_allowed() {
        let spec = ReduceSpec::new("ast.keyed", Expr::Key)
            .with_acc(lit_i64(0))
            .with_update(0, acc(0).add(cur()));
        let p = spec.compile().unwrap();
        assert!(analyze(&p).is_ok(), "key in finalize is legal");
        let out = run(&p, &i64s(&[1]));
        assert_eq!(out, vec![Val::Str("k".into())]);
    }

    #[test]
    fn extern_in_init_compiles_but_rejects() {
        let spec = ReduceSpec::new("ast.extern", acc(0))
            .with_acc(Expr::Extern(0))
            .with_update(0, acc(0).add(cur()));
        let p = spec.compile().unwrap();
        assert!(
            analyze(&p).is_err(),
            "the DSL must not launder external dependencies into combiners"
        );
    }

    #[test]
    fn count_idiom_via_values_len() {
        let spec = ReduceSpec::new("ast.count", Expr::ValuesLen);
        let p = spec.compile().unwrap();
        let a = analyze(&p).unwrap();
        assert_eq!(a.idiom, Idiom::Count);
    }

    #[test]
    fn spec_errors_are_caught() {
        let bad = ReduceSpec::new("ast.bad", acc(0))
            .with_acc(lit_i64(0))
            .with_update(3, acc(0).add(cur()));
        assert!(matches!(bad.compile(), Err(SpecError::UnknownAcc(3, 1))));

        let cur_in_init = ReduceSpec::new("ast.bad2", acc(0)).with_acc(cur());
        assert!(matches!(
            cur_in_init.compile(),
            Err(SpecError::CurOutsideUpdate)
        ));

        let cur_in_result = ReduceSpec::new("ast.bad3", cur());
        assert!(matches!(
            cur_in_result.compile(),
            Err(SpecError::CurOutsideUpdate)
        ));
    }

    #[test]
    fn end_to_end_through_mapreduce() {
        use crate::api::reducers::RirReducer;
        use crate::api::traits::Emitter;
        use crate::api::{JobConfig, MapReduce};
        let mapper = |x: &i64, em: &mut dyn Emitter<i64, f64>| em.emit(*x % 3, *x as f64);
        let reducer: RirReducer<i64, f64> =
            RirReducer::new(specs::mean_f64("ast.e2e.mean").compile().unwrap());
        let job = MapReduce::new(mapper, reducer).with_config(JobConfig::fast().with_threads(2));
        let inputs: Vec<i64> = (0..30).collect();
        let (mut out, report) = job.run_with_report(&inputs);
        assert_eq!(report.metrics.flow.label(), "combine");
        out.sort_by_key(|kv| kv.key);
        // Key 0: mean of {0,3,..,27} = 13.5
        assert_eq!(out[0].value, 13.5);
    }
}
