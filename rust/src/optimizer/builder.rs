//! Fluent construction of RIR programs.
//!
//! Benchmarks author their reducers through this builder so application
//! code stays a single expression, mirroring the anonymous-class style of
//! the paper's Figure 2. `build()` verifies the program; tests that need
//! malformed programs use `build_unchecked()`.

use super::rir::{Instr, Program, VerifyError};
use super::value::Val;

/// Fluent RIR assembler.
#[derive(Debug)]
pub struct ProgramBuilder {
    name: String,
    code: Vec<Instr>,
    max_local: Option<u8>,
}

impl ProgramBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        ProgramBuilder {
            name: name.into(),
            code: Vec::new(),
            max_local: None,
        }
    }

    fn track_local(&mut self, n: u8) {
        self.max_local = Some(self.max_local.map_or(n, |m| m.max(n)));
    }

    pub fn const_val(mut self, v: Val) -> Self {
        self.code.push(Instr::Const(v));
        self
    }

    pub fn const_i64(self, x: i64) -> Self {
        self.const_val(Val::I64(x))
    }

    pub fn const_f64(self, x: f64) -> Self {
        self.const_val(Val::F64(x))
    }

    pub fn load(mut self, n: u8) -> Self {
        self.track_local(n);
        self.code.push(Instr::Load(n));
        self
    }

    pub fn store(mut self, n: u8) -> Self {
        self.track_local(n);
        self.code.push(Instr::Store(n));
        self
    }

    pub fn load_cur(mut self) -> Self {
        self.code.push(Instr::LoadCur);
        self
    }

    pub fn load_key(mut self) -> Self {
        self.code.push(Instr::LoadKey);
        self
    }

    pub fn values_len(mut self) -> Self {
        self.code.push(Instr::ValuesLen);
        self
    }

    pub fn values_first(mut self) -> Self {
        self.code.push(Instr::ValuesFirst);
        self
    }

    pub fn values_index(mut self) -> Self {
        self.code.push(Instr::ValuesIndex);
        self
    }

    pub fn load_extern(mut self, n: u8) -> Self {
        self.code.push(Instr::LoadExtern(n));
        self
    }

    pub fn iter_start(mut self) -> Self {
        self.code.push(Instr::IterStart);
        self
    }

    pub fn iter_end(mut self) -> Self {
        self.code.push(Instr::IterEnd);
        self
    }

    pub fn break_if(mut self) -> Self {
        self.code.push(Instr::BreakIf);
        self
    }

    pub fn add(mut self) -> Self {
        self.code.push(Instr::Add);
        self
    }

    pub fn sub(mut self) -> Self {
        self.code.push(Instr::Sub);
        self
    }

    pub fn mul(mut self) -> Self {
        self.code.push(Instr::Mul);
        self
    }

    pub fn div(mut self) -> Self {
        self.code.push(Instr::Div);
        self
    }

    pub fn min(mut self) -> Self {
        self.code.push(Instr::Min);
        self
    }

    pub fn max(mut self) -> Self {
        self.code.push(Instr::Max);
        self
    }

    pub fn lt(mut self) -> Self {
        self.code.push(Instr::Lt);
        self
    }

    pub fn select(mut self) -> Self {
        self.code.push(Instr::Select);
        self
    }

    pub fn dup(mut self) -> Self {
        self.code.push(Instr::Dup);
        self
    }

    pub fn pop(mut self) -> Self {
        self.code.push(Instr::Pop);
        self
    }

    pub fn swap(mut self) -> Self {
        self.code.push(Instr::Swap);
        self
    }

    pub fn emit(mut self) -> Self {
        self.code.push(Instr::Emit);
        self
    }

    /// Finish and verify.
    pub fn build(self) -> Result<Program, VerifyError> {
        let p = self.build_unchecked();
        p.verify()?;
        Ok(p)
    }

    /// Finish without verification (tests construct malformed programs).
    pub fn build_unchecked(self) -> Program {
        let n_locals = self.max_local.map_or(0, |m| m + 1);
        Program::new(self.name, self.code, n_locals)
    }
}

/// Canonical reducer programs used across benchmarks and tests — the
/// "library" of reducers the suite needs. Each is the RIR spelling of the
/// reduce method the corresponding Phoenix benchmark writes by hand.
pub mod canon {
    use super::*;

    /// `acc = 0; for v { acc += v }; emit acc` — Word Count, Histogram,
    /// Linear Regression (per-component), PCA partial sums.
    pub fn sum_i64(name: &str) -> Program {
        ProgramBuilder::new(name)
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("canonical sum_i64 verifies")
    }

    /// f64 running sum.
    pub fn sum_f64(name: &str) -> Program {
        ProgramBuilder::new(name)
            .const_f64(0.0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("canonical sum_f64 verifies")
    }

    /// Element-wise vector sum — K-Means: the running sum of point
    /// coordinates plus count (the "state" the paper calls out as the
    /// challenge for all three frameworks; the count rides along as the
    /// final vector component).
    pub fn sum_vec(name: &str, dims: usize) -> Program {
        ProgramBuilder::new(name)
            .const_val(Val::F64Vec(vec![0.0; dims]))
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("canonical sum_vec verifies")
    }

    /// `acc = +inf; for v { acc = min(acc, v) }; emit acc`.
    pub fn min_f64(name: &str) -> Program {
        ProgramBuilder::new(name)
            .const_f64(f64::INFINITY)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .min()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("canonical min_f64 verifies")
    }

    /// `acc = -inf; for v { acc = max(acc, v) }; emit acc`.
    pub fn max_i64(name: &str) -> Program {
        ProgramBuilder::new(name)
            .const_i64(i64::MIN)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .max()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("canonical max_i64 verifies")
    }

    /// COUNT idiom: `emit values.len()` — String Match-style presence
    /// counting ("uses the size ... in the intermediate value list").
    pub fn count(name: &str) -> Program {
        ProgramBuilder::new(name)
            .values_len()
            .emit()
            .build()
            .expect("canonical count verifies")
    }

    /// FIRST idiom: `emit values[0]` — dedup-style reducers.
    pub fn first(name: &str) -> Program {
        ProgramBuilder::new(name)
            .values_first()
            .emit()
            .build()
            .expect("canonical first verifies")
    }

    /// Sum followed by a scale in finalization: `emit (sum * c)` — shows a
    /// non-trivial finalize slice.
    pub fn scaled_sum_f64(name: &str, scale: f64) -> Program {
        ProgramBuilder::new(name)
            .const_f64(0.0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .const_f64(scale)
            .mul()
            .emit()
            .build()
            .expect("canonical scaled_sum verifies")
    }

    /// A reducer with an early exit — **must be rejected** by the analyzer.
    pub fn early_exit(name: &str) -> Program {
        ProgramBuilder::new(name)
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .const_i64(100)
            .lt()
            .break_if()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("early_exit is well-formed (but not transformable)")
    }

    /// Init block reading captured state — **must be rejected** (external
    /// data dependency, paper §3.2 step 3).
    pub fn extern_seed(name: &str) -> Program {
        ProgramBuilder::new(name)
            .load_extern(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .expect("extern_seed is well-formed (but not transformable)")
    }

    /// Random access into the value list — **must be rejected**.
    pub fn random_access(name: &str) -> Program {
        ProgramBuilder::new(name)
            .const_i64(1)
            .values_index()
            .emit()
            .build()
            .expect("random_access is well-formed (but not transformable)")
    }

    /// Emit inside the loop (one output per value) — **must be rejected**
    /// for combining (it is not a fold).
    pub fn emit_in_loop(name: &str) -> Program {
        ProgramBuilder::new(name)
            .iter_start()
            .load_cur()
            .emit()
            .iter_end()
            .const_i64(0)
            .emit()
            .build()
            .expect("emit_in_loop is well-formed (but not transformable)")
    }
}

#[cfg(test)]
mod tests {
    use super::canon;
    use super::*;

    #[test]
    fn builder_counts_locals() {
        let p = ProgramBuilder::new("t")
            .const_i64(0)
            .store(3)
            .load(3)
            .emit()
            .build()
            .unwrap();
        assert_eq!(p.n_locals, 4);
    }

    #[test]
    fn canonical_programs_all_verify() {
        for p in [
            canon::sum_i64("a"),
            canon::sum_f64("b"),
            canon::sum_vec("c", 3),
            canon::min_f64("d"),
            canon::max_i64("e"),
            canon::count("f"),
            canon::first("g"),
            canon::scaled_sum_f64("h", 0.5),
            canon::early_exit("i"),
            canon::extern_seed("j"),
            canon::random_access("k"),
            canon::emit_in_loop("l"),
        ] {
            p.verify().unwrap_or_else(|e| panic!("{}: {e}", p.name));
        }
    }

    #[test]
    fn build_rejects_malformed() {
        assert!(ProgramBuilder::new("bad").add().emit().build().is_err());
    }
}
