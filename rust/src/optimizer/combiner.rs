//! Generated combiners — the paper's Figure 4 output.
//!
//! A [`Combiner`] packages the three generated methods:
//!
//! * `initialize()` — "provides an initial intermediate representation for
//!   values as a holder type";
//! * `combine(holder, v)` — "contains the code from the reduce method that
//!   implements the combining";
//! * `finalize(holder)` — "converts the intermediate representation of the
//!   value into its final form".
//!
//! Two execution strategies:
//!
//! * **Fast paths** — recognized fold shapes (`acc = acc ⊕ cur` with an
//!   identity finalize) compile to direct Rust operations on an unboxed
//!   holder. This is the analogue of the paper's observation that the
//!   rewrite "enacts the dynamic compiler to further improve the generated
//!   machine code" (scalar replacement of the boxed accumulator).
//! * **Generic interpretation** — any accepted fold runs its init/body/
//!   final slices in the RIR interpreter against a boxed locals holder.
//!   Semantics are identical; tests assert fast ≡ generic.

use std::sync::Arc;

use super::analyze::{Analysis, Idiom};
use super::interp::{run_slice, EvalError, ReduceCtx};
use super::rir::{Instr, Program};
use super::value::{Ty, Val};
use crate::api::traits::HeapSized;

/// Recognized single-accumulator fold shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FastPath {
    AddI64,
    AddF64,
    AddVec,
    MinF64,
    MaxI64,
    Count,
    First,
}

/// The mutable intermediate state — the paper's Holder object.
#[derive(Clone, Debug, PartialEq)]
pub enum Holder {
    /// Generic: the accumulator locals of the sliced program.
    Locals(Vec<Val>),
    /// Unboxed fast-path accumulators.
    I64(i64),
    F64(f64),
    Vec(Vec<f64>),
    /// FIRST idiom: the first value seen, if any.
    Opt(Option<Val>),
}

impl HeapSized for Holder {
    fn heap_bytes(&self) -> u64 {
        match self {
            // One mutable boxing object (paper §3.1: "a private
            // encapsulating object").
            Holder::I64(_) | Holder::F64(_) => 24,
            Holder::Opt(v) => 24 + v.as_ref().map_or(0, |v| v.heap_bytes()),
            Holder::Vec(v) => 24 + 8 * v.len() as u64,
            Holder::Locals(ls) => 24 + ls.iter().map(|v| v.heap_bytes()).sum::<u64>(),
        }
    }
}

/// A generated combiner for one reducer class.
#[derive(Clone, Debug)]
pub struct Combiner {
    program: Arc<Program>,
    analysis: Analysis,
    fast: Option<FastPath>,
}

impl Combiner {
    pub(crate) fn new(program: Arc<Program>, analysis: Analysis, fast: Option<FastPath>) -> Self {
        Combiner {
            program,
            analysis,
            fast,
        }
    }

    pub fn idiom(&self) -> Idiom {
        self.analysis.idiom
    }

    pub fn fast_path(&self) -> Option<FastPath> {
        self.fast
    }

    pub fn program_name(&self) -> &str {
        &self.program.name
    }

    /// Force the generic interpreter even where a fast path exists
    /// (equivalence testing and the ablation bench).
    pub fn without_fast_path(&self) -> Combiner {
        Combiner {
            program: Arc::clone(&self.program),
            analysis: self.analysis.clone(),
            fast: None,
        }
    }

    /// `Holder initialize();`
    pub fn initialize(&self) -> Holder {
        if let Some(fp) = self.fast {
            return match fp {
                FastPath::AddI64 => Holder::I64(init_i64(&self.program, &self.analysis, 0)),
                FastPath::Count => Holder::I64(0),
                FastPath::AddF64 => Holder::F64(init_f64(&self.program, &self.analysis, 0.0)),
                FastPath::MinF64 => {
                    Holder::F64(init_f64(&self.program, &self.analysis, f64::INFINITY))
                }
                FastPath::MaxI64 => Holder::I64(init_i64(&self.program, &self.analysis, i64::MIN)),
                FastPath::AddVec => Holder::Vec(init_vec(&self.program, &self.analysis)),
                FastPath::First => Holder::Opt(None),
            };
        }
        match self.analysis.idiom {
            Idiom::Count => Holder::I64(0),
            Idiom::First => Holder::Opt(None),
            Idiom::Fold => {
                let mut locals = vec![Val::Nil; self.program.n_locals as usize];
                let key = Val::Nil;
                let ctx = ReduceCtx::new(&key, &[]);
                let (lo, hi) = self.analysis.init;
                run_slice(&self.program, lo, hi, &mut locals, None, &ctx)
                    .expect("init slice verified");
                Holder::Locals(locals)
            }
        }
    }

    /// `void combine(Holder, V);`
    pub fn combine(&self, holder: &mut Holder, v: &Val) -> Result<(), EvalError> {
        if let Some(fp) = self.fast {
            fast_combine(fp, holder, v);
            return Ok(());
        }
        match self.analysis.idiom {
            Idiom::Count => {
                if let Holder::I64(n) = holder {
                    *n += 1;
                }
                Ok(())
            }
            Idiom::First => {
                if let Holder::Opt(slot) = holder {
                    if slot.is_none() {
                        *slot = Some(v.clone());
                    }
                }
                Ok(())
            }
            Idiom::Fold => {
                let locals = match holder {
                    Holder::Locals(ls) => ls,
                    _ => unreachable!("fold uses Locals holder"),
                };
                let key = Val::Nil;
                let ctx = ReduceCtx::new(&key, &[]);
                let (lo, hi) = self.analysis.body;
                run_slice(&self.program, lo, hi, locals, Some(v), &ctx)?;
                Ok(())
            }
        }
    }

    /// `V finalize(Holder);` — `key` is available at finalization, matching
    /// the reduce method's signature.
    pub fn finalize(&self, holder: Holder, key: &Val) -> Result<Val, EvalError> {
        if let Some(fp) = self.fast {
            // Fast paths have identity finalize except the idioms.
            return match (fp, holder) {
                (FastPath::Count, Holder::I64(n)) => self.finalize_count(n, key),
                (FastPath::First, Holder::Opt(v)) => self.finalize_first(v, key),
                (_, Holder::I64(x)) => Ok(Val::I64(x)),
                (_, Holder::F64(x)) => Ok(Val::F64(x)),
                (_, Holder::Vec(x)) => Ok(Val::F64Vec(x)),
                _ => unreachable!("fast holder shape"),
            };
        }
        match self.analysis.idiom {
            Idiom::Count => {
                let n = match holder {
                    Holder::I64(n) => n,
                    _ => unreachable!(),
                };
                self.finalize_count(n, key)
            }
            Idiom::First => {
                let v = match holder {
                    Holder::Opt(v) => v,
                    _ => unreachable!(),
                };
                self.finalize_first(v, key)
            }
            Idiom::Fold => {
                let mut locals = match holder {
                    Holder::Locals(ls) => ls,
                    _ => unreachable!(),
                };
                let ctx = ReduceCtx::new(key, &[]);
                let (lo, hi) = self.analysis.fin;
                let out = run_slice(&self.program, lo, hi, &mut locals, None, &ctx)?;
                Ok(out.expect("finalize slice ends in Emit"))
            }
        }
    }

    /// COUNT: re-run the (loop-free) program with `values.len()` replaced by
    /// the held count.
    fn finalize_count(&self, n: i64, key: &Val) -> Result<Val, EvalError> {
        let mut ctx = ReduceCtx::new(key, &[]);
        ctx.fake_len = Some(n);
        let mut locals = vec![Val::Nil; self.program.n_locals as usize];
        let out = run_slice(&self.program, 0, self.program.code.len(), &mut locals, None, &ctx)?;
        Ok(out.expect("count program ends in Emit"))
    }

    /// FIRST: re-run with `values[0]` replaced by the held value.
    fn finalize_first(&self, v: Option<Val>, key: &Val) -> Result<Val, EvalError> {
        let first = v.expect("finalize called for a key with at least one emit");
        let mut ctx = ReduceCtx::new(key, &[]);
        ctx.fake_first = Some(first);
        let mut locals = vec![Val::Nil; self.program.n_locals as usize];
        let out = run_slice(&self.program, 0, self.program.code.len(), &mut locals, None, &ctx)?;
        Ok(out.expect("first program ends in Emit"))
    }

    /// Expected holder heap footprint for memsim accounting.
    pub fn holder_bytes(&self) -> u64 {
        self.initialize().heap_bytes()
    }
}

#[inline]
fn fast_combine(fp: FastPath, holder: &mut Holder, v: &Val) {
    match (fp, holder, v) {
        (FastPath::AddI64, Holder::I64(acc), Val::I64(x)) => *acc = acc.wrapping_add(*x),
        (FastPath::AddF64, Holder::F64(acc), Val::F64(x)) => *acc += x,
        (FastPath::MinF64, Holder::F64(acc), Val::F64(x)) => *acc = acc.min(*x),
        (FastPath::MaxI64, Holder::I64(acc), Val::I64(x)) => *acc = (*acc).max(*x),
        (FastPath::AddVec, Holder::Vec(acc), Val::F64Vec(x)) => {
            debug_assert_eq!(acc.len(), x.len());
            for (a, b) in acc.iter_mut().zip(x) {
                *a += b;
            }
        }
        (FastPath::Count, Holder::I64(acc), _) => *acc += 1,
        (FastPath::First, Holder::Opt(slot), v) => {
            if slot.is_none() {
                *slot = Some(v.clone());
            }
        }
        (fp, h, v) => unreachable!("fast path {fp:?} holder/value mismatch: {h:?} {v:?}"),
    }
}

/// Run the init slice and pull out the single accumulator's initial value.
fn init_i64(prog: &Program, a: &Analysis, default: i64) -> i64 {
    init_local(prog, a).and_then(|v| v.as_i64()).unwrap_or(default)
}

fn init_f64(prog: &Program, a: &Analysis, default: f64) -> f64 {
    init_local(prog, a).and_then(|v| v.as_f64()).unwrap_or(default)
}

fn init_vec(prog: &Program, a: &Analysis) -> Vec<f64> {
    match init_local(prog, a) {
        Some(Val::F64Vec(v)) => v,
        _ => Vec::new(),
    }
}

fn init_local(prog: &Program, a: &Analysis) -> Option<Val> {
    let acc = *a.acc_locals.first()? as usize;
    let mut locals = vec![Val::Nil; prog.n_locals as usize];
    let key = Val::Nil;
    let ctx = ReduceCtx::new(&key, &[]);
    run_slice(prog, a.init.0, a.init.1, &mut locals, None, &ctx).ok()?;
    Some(locals[acc].clone())
}

/// Detect a fast path from the analysis: single accumulator, body of the
/// exact shape `Load(a); LoadCur; ⊕; Store(a)`, identity finalize
/// `Load(a); Emit`. (The idioms always have fast paths.)
pub(crate) fn detect_fast_path(prog: &Program, a: &Analysis) -> Option<FastPath> {
    match a.idiom {
        Idiom::Count => return Some(FastPath::Count),
        Idiom::First => return Some(FastPath::First),
        Idiom::Fold => {}
    }
    if a.acc_locals.len() != 1 {
        return None;
    }
    let acc = a.acc_locals[0];
    let body = &prog.code[a.body.0..a.body.1];
    let op = match body {
        [Instr::Load(l1), Instr::LoadCur, op, Instr::Store(l2)]
            if *l1 == acc && *l2 == acc =>
        {
            op
        }
        _ => return None,
    };
    let fin = &prog.code[a.fin.0..a.fin.1];
    if !matches!(fin, [Instr::Load(l), Instr::Emit] if *l == acc) {
        return None;
    }
    let ty = a.holder_ty.get(acc as usize)?;
    match (op, ty) {
        (Instr::Add, Ty::I64) => Some(FastPath::AddI64),
        (Instr::Add, Ty::F64) => Some(FastPath::AddF64),
        (Instr::Add, Ty::F64Vec) => Some(FastPath::AddVec),
        (Instr::Min, Ty::F64) => Some(FastPath::MinF64),
        (Instr::Max, Ty::I64) => Some(FastPath::MaxI64),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::analyze::analyze;
    use crate::optimizer::builder::canon;
    use crate::optimizer::transform::transform;

    fn combiner_for(p: Program) -> Combiner {
        let a = analyze(&p).unwrap();
        transform(Arc::new(p), a)
    }

    fn fold_all(c: &Combiner, vals: &[Val]) -> Val {
        let mut h = c.initialize();
        for v in vals {
            c.combine(&mut h, v).unwrap();
        }
        c.finalize(h, &Val::Str("k".into())).unwrap()
    }

    #[test]
    fn sum_combiner_matches_reduce() {
        let c = combiner_for(canon::sum_i64("s"));
        assert_eq!(c.fast_path(), Some(FastPath::AddI64));
        let vals: Vec<Val> = (1..=100).map(Val::I64).collect();
        assert_eq!(fold_all(&c, &vals), Val::I64(5050));
    }

    #[test]
    fn generic_equals_fast() {
        for (p, vals) in [
            (
                canon::sum_i64("a"),
                (1..=50).map(Val::I64).collect::<Vec<_>>(),
            ),
            (
                canon::max_i64("b"),
                vec![Val::I64(3), Val::I64(99), Val::I64(-5)],
            ),
            (
                canon::min_f64("c"),
                vec![Val::F64(2.5), Val::F64(-1.0), Val::F64(7.0)],
            ),
        ] {
            let fast = combiner_for(p);
            assert!(fast.fast_path().is_some());
            let generic = fast.without_fast_path();
            assert_eq!(
                fold_all(&fast, &vals),
                fold_all(&generic, &vals),
                "fast != generic for {}",
                fast.program_name()
            );
        }
    }

    #[test]
    fn vec_sum_combines_elementwise() {
        let c = combiner_for(canon::sum_vec("v", 2));
        assert_eq!(c.fast_path(), Some(FastPath::AddVec));
        let out = fold_all(
            &c,
            &[
                Val::F64Vec(vec![1.0, 10.0]),
                Val::F64Vec(vec![2.0, 20.0]),
            ],
        );
        assert_eq!(out, Val::F64Vec(vec![3.0, 30.0]));
    }

    #[test]
    fn scaled_sum_uses_generic_finalize() {
        let c = combiner_for(canon::scaled_sum_f64("ss", 0.25));
        assert_eq!(c.fast_path(), None, "non-identity finalize → generic");
        let out = fold_all(&c, &[Val::F64(4.0), Val::F64(4.0)]);
        assert_eq!(out, Val::F64(2.0));
    }

    #[test]
    fn count_idiom_combiner() {
        let c = combiner_for(canon::count("c"));
        assert_eq!(c.idiom(), Idiom::Count);
        let vals = vec![Val::Str("x".into()); 7];
        assert_eq!(fold_all(&c, &vals), Val::I64(7));
    }

    #[test]
    fn first_idiom_combiner() {
        let c = combiner_for(canon::first("f"));
        let out = fold_all(&c, &[Val::I64(42), Val::I64(1), Val::I64(2)]);
        assert_eq!(out, Val::I64(42));
    }

    #[test]
    fn holder_bytes_reasonable() {
        let c = combiner_for(canon::sum_i64("s"));
        assert!(c.holder_bytes() >= 16 && c.holder_bytes() <= 64);
        let cv = combiner_for(canon::sum_vec("v", 8));
        assert!(cv.holder_bytes() >= 24 + 64);
    }
}
