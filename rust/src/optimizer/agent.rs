//! The optimizer agent — the Java-agent analogue (paper §3.2).
//!
//! In the paper, a Java agent instruments *every* loaded class, detects
//! subclasses of `Reducer`, and rewrites their bytecode at class-load time.
//! Here, the agent sits on the reducer-registration path of
//! [`crate::api::MapReduce`]: every reducer passes through
//! [`OptimizerAgent::process`], which runs **detection** (cheap structural
//! check, timed), then **transformation** (PDG analysis + slicing + fast
//! path compilation, timed), caches the outcome per reducer class, and
//! reports the per-class timing statistics behind the paper's §4.3 numbers
//! (81 µs detection / 7.6 ms transformation on their JVM).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::analyze::{analyze, detect, Reject};
use super::combiner::Combiner;
use super::rir::Program;
use super::transform::transform;
use crate::api::config::OptimizeMode;
use crate::stats::StageAdapt;
use crate::util::timer::{Samples, Stopwatch};

/// Outcome of processing one reducer class.
#[derive(Clone, Debug)]
pub enum Decision {
    /// Transformed: run the combining flow with this combiner.
    Combine(Combiner),
    /// Analysis rejected the reducer: run the reduce flow. The reason is
    /// kept for diagnostics (`mr4r explain`).
    Fallback(Reject),
    /// The reducer is opaque (native closure): never optimizable.
    Opaque,
}

impl Decision {
    pub fn combiner(&self) -> Option<&Combiner> {
        match self {
            Decision::Combine(c) => Some(c),
            _ => None,
        }
    }

    pub fn is_optimized(&self) -> bool {
        matches!(self, Decision::Combine(_))
    }
}

/// Which semantic channel supplied a stage's combining rewrite.
///
/// The paper has exactly one channel: semantics *inferred* from the
/// reducer's bytecode (here, its RIR) by detection + analysis. The keyed
/// dataset algebra ([`crate::api::keyed`]) adds a second: semantics
/// *declared* by the user through the [`crate::api::keyed::Aggregator`]
/// holder triple and its `ASSOCIATIVE`/`COMMUTATIVE` markers (the
/// Casper-style contract surface). [`crate::coordinator::pipeline::FlowMetrics`]
/// reports which channel fired for each executed stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombinerSource {
    /// Declared at the API layer: the user supplied `init`/`combine`/
    /// `finish` plus the algebraic markers; nothing to analyze.
    Declared,
    /// Inferred from the reducer's RIR by the agent's detection +
    /// transformation passes (paper §3).
    Inferred,
}

impl CombinerSource {
    pub fn label(self) -> &'static str {
        match self {
            CombinerSource::Declared => "declared",
            CombinerSource::Inferred => "inferred",
        }
    }
}

/// Per-agent timing statistics (paper §4.3).
#[derive(Clone, Debug, Default)]
pub struct AgentStats {
    /// Seconds per detection pass (one per processed class).
    pub detection: Samples,
    /// Seconds per transformation pass (only classes that detected).
    pub transformation: Samples,
    /// Classes that ended optimized.
    pub optimized: usize,
    /// Classes that fell back with a rejection.
    pub rejected: usize,
    /// Opaque (closure) reducers seen.
    pub opaque: usize,
    /// Declared aggregators accepted for in-map combining (associative
    /// and commutative markers both present).
    pub declared_accepted: usize,
    /// Declared aggregators refused the combining flow (a marker is
    /// missing, so per-key folding order cannot be freely rearranged).
    pub declared_rejected: usize,
    /// Cache hits (class processed before).
    pub cache_hits: usize,
    /// Whole-plan passes run ([`OptimizerAgent::plan`]).
    pub plans: usize,
    /// Element-wise stages fused into a downstream map phase.
    pub fused_stages: usize,
    /// Reduce→stage handoffs that streamed shard outputs.
    pub streamed_handoffs: usize,
}

/// Whole-plan view of one logical stage, built by the planner
/// ([`crate::coordinator::planner::lower`]) from the DAG a lazy
/// [`crate::api::plan::Dataset`] records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageShape {
    /// The plan's input source.
    Source,
    /// An element-wise operator (`map`/`filter`/`flat_map`).
    ElementWise {
        /// Optimizer mode captured when the stage was recorded.
        mode: OptimizeMode,
    },
    /// A `map_reduce` stage. `follows_reduce` is true when its input is
    /// the output of an upstream reduce stage (a streamable handoff).
    Reduce {
        mode: OptimizeMode,
        follows_reduce: bool,
    },
}

/// Physical placement the whole-plan pass picks for one logical stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageDecision {
    /// Nothing to decide (source stages).
    Input,
    /// Element-wise op composed into the downstream map phase — no
    /// intermediate `Vec` between the op and the consumer.
    Fuse,
    /// Element-wise op materializes its output (optimizer off).
    Materialize,
    /// Reduce stage consuming the upstream stage's shard outputs as a
    /// stream, skipping the `JobOutput` round-trip.
    StreamInput,
    /// Reduce stage consuming a materialized input (plan heads, or
    /// optimizer off).
    MaterializeInput,
}

/// The agent. Cheap to clone (shared internals), thread-safe.
#[derive(Clone, Default)]
pub struct OptimizerAgent {
    inner: Arc<Mutex<AgentInner>>,
}

#[derive(Default)]
struct AgentInner {
    cache: HashMap<String, Decision>,
    stats: AgentStats,
}

/// Whether optimization is attempted (the paper's optimizer on/off switch
/// used throughout Figures 7–10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgentMode {
    Enabled,
    Disabled,
}

impl OptimizerAgent {
    pub fn new() -> Self {
        Self::default()
    }

    /// Process a reducer program: detection, then transformation, with
    /// per-class caching (a class is rewritten once at "load time").
    pub fn process(&self, program: &Program) -> Decision {
        let mut inner = self.inner.lock().unwrap();
        if let Some(hit) = inner.cache.get(&program.name).cloned() {
            inner.stats.cache_hits += 1;
            return hit;
        }

        // Detection: the per-class structural scan the agent pays on every
        // candidate (paper: 81 µs average).
        let sw = Stopwatch::start();
        let detected = detect(program);
        inner.stats.detection.push(sw.secs());

        let decision = if !detected {
            inner.stats.rejected += 1;
            Decision::Fallback(Reject::NoLoopNoIdiom)
        } else {
            // Transformation: PDG + slicing + fast-path compile
            // (paper: 7.6 ms average).
            let sw = Stopwatch::start();
            let d = match analyze(program) {
                Ok(a) => {
                    inner.stats.optimized += 1;
                    Decision::Combine(transform(Arc::new(program.clone()), a))
                }
                Err(r) => {
                    inner.stats.rejected += 1;
                    Decision::Fallback(r)
                }
            };
            inner.stats.transformation.push(sw.secs());
            d
        };

        inner
            .cache
            .insert(program.name.clone(), decision.clone());
        decision
    }

    /// The whole-plan pass: given the logical stages of a lazy plan,
    /// decide each stage's physical placement. This generalizes the
    /// per-class rewrite (paper §3: swap the emitter implementation
    /// behind an unchanged API) to the plan level:
    ///
    /// * element-wise stages fuse into the next map phase, so no
    ///   intermediate `Vec` is materialized between them and their
    ///   consumer (unless the stage was recorded with the optimizer off);
    /// * a reduce stage that feeds another stage hands its shard outputs
    ///   over as a stream, skipping the `JobOutput` round-trip.
    ///
    /// Per-reduce-stage combiner insertion is *not* decided here — it
    /// stays on the per-class [`OptimizerAgent::process`] path, which the
    /// stage executor consults exactly as eager jobs do.
    ///
    /// Like everything else the agent does, this runs transparently: the
    /// application records `map`/`filter`/`map_reduce` calls and never
    /// sees the placement.
    pub fn plan(&self, stages: &[StageShape]) -> Vec<StageDecision> {
        self.plan_with(stages, &[])
    }

    /// [`OptimizerAgent::plan`] with per-stage adaptive hints from the
    /// session's feedback store ([`crate::stats::StatsStore`]), as
    /// derived by the planner. This is the *single* planning authority:
    /// the real lowering pass and the `explain()` preview both funnel
    /// through the same pure policy with the same hints, which is what
    /// pins preview ≡ executed decisions. Placement itself is
    /// deliberately hint-independent today — adaptive hints tune
    /// *execution* (shard counts, flow choice, hot-key routing), not
    /// fusion or handoff streaming, so hinted and unhinted placements
    /// coincide — but every future hint-sensitive placement rule must
    /// land here, behind both entry points at once.
    pub fn plan_with(
        &self,
        stages: &[StageShape],
        hints: &[Option<StageAdapt>],
    ) -> Vec<StageDecision> {
        let (decisions, fused, streamed) = Self::decide_with(stages, hints);
        let mut inner = self.inner.lock().unwrap();
        inner.stats.plans += 1;
        inner.stats.fused_stages += fused;
        inner.stats.streamed_handoffs += streamed;
        decisions
    }

    /// [`OptimizerAgent::plan`] without the statistics side effects — the
    /// observational pass behind `Dataset::explain()`, which must not
    /// make a never-executed plan look like a run.
    pub fn plan_preview(&self, stages: &[StageShape]) -> Vec<StageDecision> {
        self.plan_preview_with(stages, &[])
    }

    /// [`OptimizerAgent::plan_with`] without the statistics side effects
    /// — the preview twin, guaranteed to see the identical hint slice.
    pub fn plan_preview_with(
        &self,
        stages: &[StageShape],
        hints: &[Option<StageAdapt>],
    ) -> Vec<StageDecision> {
        Self::decide_with(stages, hints).0
    }

    /// The pure placement policy shared by the plan and preview entry
    /// points, hints included.
    fn decide_with(
        stages: &[StageShape],
        hints: &[Option<StageAdapt>],
    ) -> (Vec<StageDecision>, usize, usize) {
        debug_assert!(
            hints.is_empty() || hints.len() == stages.len(),
            "hint slice must be empty or stage-aligned"
        );
        Self::decide(stages)
    }

    /// The hint-independent core of the placement policy.
    fn decide(stages: &[StageShape]) -> (Vec<StageDecision>, usize, usize) {
        let mut decisions = Vec::with_capacity(stages.len());
        let mut fused = 0usize;
        let mut streamed = 0usize;
        for stage in stages {
            decisions.push(match stage {
                StageShape::Source => StageDecision::Input,
                StageShape::ElementWise { mode } => {
                    if matches!(mode, OptimizeMode::Off) {
                        StageDecision::Materialize
                    } else {
                        fused += 1;
                        StageDecision::Fuse
                    }
                }
                StageShape::Reduce {
                    mode,
                    follows_reduce,
                } => {
                    if *follows_reduce && !matches!(mode, OptimizeMode::Off) {
                        streamed += 1;
                        StageDecision::StreamInput
                    } else {
                        StageDecision::MaterializeInput
                    }
                }
            });
        }
        (decisions, fused, streamed)
    }

    /// The declared-semantics channel: a keyed stage registers its
    /// [`crate::api::keyed::Aggregator`]'s algebraic markers and asks
    /// whether the in-map combining flow may run. There is no detection
    /// or transformation pass to time — the declaration *is* the analysis
    /// result, which is exactly the co-design trade: the inferred channel
    /// pays §4.3's per-class analysis cost and works on unmodified
    /// reducers; the declared channel costs the user three methods and
    /// two markers and can never be rejected for an analysis blind spot.
    ///
    /// Combining is granted only when the fold is declared associative
    /// *and* commutative: the sharded holder table applies `combine` in
    /// whatever order worker emits interleave, so any order-sensitive
    /// fold must keep the reduce flow (exactly why Spark's `reduceByKey`
    /// demands both properties while `groupByKey` never map-combines).
    pub fn process_declared(&self, _class: &str, associative: bool, commutative: bool) -> bool {
        let accept = associative && commutative;
        let mut inner = self.inner.lock().unwrap();
        if accept {
            inner.stats.declared_accepted += 1;
        } else {
            inner.stats.declared_rejected += 1;
        }
        accept
    }

    /// Record an opaque (closure) reducer passing the registration hook.
    pub fn note_opaque(&self) {
        self.inner.lock().unwrap().stats.opaque += 1;
    }

    pub fn stats(&self) -> AgentStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Drop the cache (tests and the overhead harness re-measure cold).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.cache.clear();
        inner.stats = AgentStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::builder::canon;

    #[test]
    fn accepts_and_caches() {
        let agent = OptimizerAgent::new();
        let p = canon::sum_i64("wc-sum");
        assert!(agent.process(&p).is_optimized());
        assert!(agent.process(&p).is_optimized());
        let s = agent.stats();
        assert_eq!(s.optimized, 1, "second call must hit the cache");
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.detection.len(), 1);
        assert_eq!(s.transformation.len(), 1);
    }

    #[test]
    fn rejects_with_reason() {
        let agent = OptimizerAgent::new();
        match agent.process(&canon::early_exit("ee")) {
            Decision::Fallback(Reject::EarlyExit) => {}
            other => panic!("expected EarlyExit fallback, got {other:?}"),
        }
        assert_eq!(agent.stats().rejected, 1);
    }

    #[test]
    fn detection_cheaper_than_transformation() {
        let agent = OptimizerAgent::new();
        // Process the full canonical suite to get stable samples.
        for p in [
            canon::sum_i64("a"),
            canon::sum_f64("b"),
            canon::sum_vec("c", 3),
            canon::min_f64("d"),
            canon::max_i64("e"),
            canon::count("f"),
            canon::scaled_sum_f64("g", 2.0),
        ] {
            agent.process(&p);
        }
        let s = agent.stats();
        assert_eq!(s.optimized, 7);
        // The paper's relationship: detection ≪ transformation.
        assert!(
            s.detection.mean() < s.transformation.mean(),
            "detect {} !< transform {}",
            s.detection.mean(),
            s.transformation.mean()
        );
    }

    #[test]
    fn whole_plan_pass_fuses_and_streams() {
        use crate::api::config::OptimizeMode;
        let agent = OptimizerAgent::new();
        let shape = [
            StageShape::Source,
            StageShape::Reduce {
                mode: OptimizeMode::Auto,
                follows_reduce: false,
            },
            StageShape::ElementWise {
                mode: OptimizeMode::Auto,
            },
            StageShape::Reduce {
                mode: OptimizeMode::Auto,
                follows_reduce: true,
            },
        ];
        let d = agent.plan(&shape);
        assert_eq!(
            d,
            vec![
                StageDecision::Input,
                StageDecision::MaterializeInput,
                StageDecision::Fuse,
                StageDecision::StreamInput,
            ]
        );
        let s = agent.stats();
        assert_eq!((s.plans, s.fused_stages, s.streamed_handoffs), (1, 1, 1));
    }

    #[test]
    fn whole_plan_pass_respects_optimizer_off() {
        use crate::api::config::OptimizeMode;
        let agent = OptimizerAgent::new();
        let shape = [
            StageShape::Source,
            StageShape::ElementWise {
                mode: OptimizeMode::Off,
            },
            StageShape::Reduce {
                mode: OptimizeMode::Off,
                follows_reduce: false,
            },
            StageShape::Reduce {
                mode: OptimizeMode::Off,
                follows_reduce: true,
            },
        ];
        let d = agent.plan(&shape);
        assert_eq!(
            d,
            vec![
                StageDecision::Input,
                StageDecision::Materialize,
                StageDecision::MaterializeInput,
                StageDecision::MaterializeInput,
            ]
        );
        assert_eq!(agent.stats().fused_stages, 0);
        assert_eq!(agent.stats().streamed_handoffs, 0);
    }

    #[test]
    fn declared_channel_requires_both_markers() {
        let agent = OptimizerAgent::new();
        assert!(agent.process_declared("sum", true, true));
        assert!(!agent.process_declared("concat", true, false));
        assert!(!agent.process_declared("sub", false, true));
        let s = agent.stats();
        assert_eq!((s.declared_accepted, s.declared_rejected), (1, 2));
    }

    #[test]
    fn hinted_plan_and_preview_agree() {
        use crate::api::config::OptimizeMode;
        let agent = OptimizerAgent::new();
        let shape = [
            StageShape::Source,
            StageShape::ElementWise {
                mode: OptimizeMode::Auto,
            },
            StageShape::Reduce {
                mode: OptimizeMode::Auto,
                follows_reduce: false,
            },
        ];
        let hints = vec![
            None,
            None,
            Some(StageAdapt {
                shard_override: Some(16),
                ..StageAdapt::default()
            }),
        ];
        let preview = agent.plan_preview_with(&shape, &hints);
        assert_eq!(agent.stats().plans, 0, "preview must not count as a run");
        let ran = agent.plan_with(&shape, &hints);
        assert_eq!(preview, ran, "preview and plan share one policy");
        assert_eq!(ran, agent.plan_preview(&shape), "hints never move placement");
    }

    #[test]
    fn opaque_reducers_counted() {
        let agent = OptimizerAgent::new();
        agent.note_opaque();
        assert_eq!(agent.stats().opaque, 1);
    }

    #[test]
    fn clear_resets() {
        let agent = OptimizerAgent::new();
        agent.process(&canon::sum_i64("x"));
        agent.clear();
        let s = agent.stats();
        assert_eq!(s.optimized, 0);
        assert_eq!(s.detection.len(), 0);
    }
}
