//! The RIR interpreter.
//!
//! Plays the role of the JVM executing the user's original `reduce`
//! bytecode: the **unoptimized** reduce flow runs whole programs over the
//! collected value lists via [`run_reduce`]; the **generic** combining flow
//! runs transformed slices via [`run_slice`] (recognized patterns are
//! instead compiled to native closures in
//! [`crate::optimizer::combiner`] — the "dynamic compiler" analogue).

use super::rir::{Instr, Program};
use super::value::{TypeError, Val};

/// Evaluation errors (verified programs over well-typed inputs do not hit
/// these; they guard tests and fuzzing).
#[derive(Clone, Debug, PartialEq)]
pub enum EvalError {
    Type { pc: usize, err: TypeError },
    Underflow { pc: usize },
    BadIndex { pc: usize },
    BadExtern { pc: usize, slot: u8 },
    BadCondition { pc: usize },
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::Type { pc, err } => write!(f, "type error at pc {pc}: {err}"),
            EvalError::Underflow { pc } => write!(f, "stack underflow at pc {pc}"),
            EvalError::BadIndex { pc } => write!(
                f,
                "ValuesFirst/ValuesIndex on empty or out-of-range value list at pc {pc}"
            ),
            EvalError::BadExtern { pc, slot } => {
                write!(f, "LoadExtern({slot}) with no such extern at pc {pc}")
            }
            EvalError::BadCondition { pc } => write!(f, "BreakIf on non-boolean at pc {pc}"),
        }
    }
}

impl std::error::Error for EvalError {}

/// The execution context for one `reduce(key, values, emitter)` call.
pub struct ReduceCtx<'a> {
    pub key: &'a Val,
    pub values: &'a [Val],
    /// Captured environment for `LoadExtern` (usually empty).
    pub externs: &'a [Val],
    /// Override for `ValuesLen` — how the COUNT-idiom combiner finalizes:
    /// the original program is re-run with the held count substituted for
    /// the (never materialized) value list's length.
    pub fake_len: Option<i64>,
    /// Override for `ValuesFirst` — the FIRST-idiom analogue.
    pub fake_first: Option<Val>,
}

impl<'a> ReduceCtx<'a> {
    pub fn new(key: &'a Val, values: &'a [Val]) -> Self {
        ReduceCtx {
            key,
            values,
            externs: &[],
            fake_len: None,
            fake_first: None,
        }
    }

    pub fn with_externs(mut self, externs: &'a [Val]) -> Self {
        self.externs = externs;
        self
    }
}

/// Run a full reducer program; every `Emit` invokes `emit` with the value.
pub fn run_reduce(
    prog: &Program,
    ctx: &ReduceCtx<'_>,
    mut emit: impl FnMut(Val),
) -> Result<(), EvalError> {
    let mut locals = vec![Val::Nil; prog.n_locals as usize];
    let mut stack: Vec<Val> = Vec::with_capacity(8);
    exec_range(
        prog,
        0,
        prog.code.len(),
        ctx,
        &mut locals,
        &mut stack,
        None,
        &mut emit,
    )
}

/// Run a straight-line slice `[lo, hi)` of a program with the given locals
/// and optional current value; returns the value left for `Emit` if the
/// slice ends with one. Used by the generic combiner
/// (`initialize`/`combine`/`finalize` are all slices).
pub fn run_slice(
    prog: &Program,
    lo: usize,
    hi: usize,
    locals: &mut [Val],
    cur: Option<&Val>,
    ctx: &ReduceCtx<'_>,
) -> Result<Option<Val>, EvalError> {
    let mut stack: Vec<Val> = Vec::with_capacity(8);
    let mut emitted = None;
    exec_range(prog, lo, hi, ctx, locals, &mut stack, cur, &mut |v| {
        emitted = Some(v)
    })?;
    Ok(emitted)
}

/// Core evaluator over `[lo, hi)`. `cur_override` supplies `LoadCur` for
/// slice execution; full-program execution iterates `ctx.values` at the
/// loop construct instead.
#[allow(clippy::too_many_arguments)]
fn exec_range(
    prog: &Program,
    lo: usize,
    hi: usize,
    ctx: &ReduceCtx<'_>,
    locals: &mut [Val],
    stack: &mut Vec<Val>,
    cur_override: Option<&Val>,
    emit: &mut impl FnMut(Val),
) -> Result<(), EvalError> {
    let mut pc = lo;
    while pc < hi {
        match &prog.code[pc] {
            Instr::IterStart => {
                // Find matching IterEnd (verifier guarantees one, no nesting).
                let end = prog.code[pc + 1..hi]
                    .iter()
                    .position(|i| matches!(i, Instr::IterEnd))
                    .map(|off| pc + 1 + off)
                    .expect("verified program has matching IterEnd");
                'values: for v in ctx.values {
                    // Execute the body once per value; BreakIf exits.
                    let mut body_pc = pc + 1;
                    while body_pc < end {
                        match &prog.code[body_pc] {
                            Instr::BreakIf => {
                                let c = stack.pop().ok_or(EvalError::Underflow { pc: body_pc })?;
                                match c {
                                    Val::Bool(true) => break 'values,
                                    Val::Bool(false) => {}
                                    _ => return Err(EvalError::BadCondition { pc: body_pc }),
                                }
                            }
                            _ => step(prog, body_pc, ctx, locals, stack, Some(v), emit)?,
                        }
                        body_pc += 1;
                    }
                }
                pc = end + 1;
                continue;
            }
            Instr::IterEnd => {
                // Only reachable when executing a slice that includes a bare
                // IterEnd — treat as a no-op boundary.
            }
            Instr::BreakIf => {
                // BreakIf outside the interpreted loop (slice execution):
                // drop the condition; the combiner path never slices programs
                // containing BreakIf (the analyzer rejects them first).
                stack.pop().ok_or(EvalError::Underflow { pc })?;
            }
            _ => step(prog, pc, ctx, locals, stack, cur_override, emit)?,
        }
        pc += 1;
    }
    Ok(())
}

/// Execute one non-control instruction.
fn step(
    prog: &Program,
    pc: usize,
    ctx: &ReduceCtx<'_>,
    locals: &mut [Val],
    stack: &mut Vec<Val>,
    cur: Option<&Val>,
    emit: &mut impl FnMut(Val),
) -> Result<(), EvalError> {
    let pop = |stack: &mut Vec<Val>| stack.pop().ok_or(EvalError::Underflow { pc });
    let bin = |stack: &mut Vec<Val>,
               f: fn(&Val, &Val) -> Result<Val, TypeError>|
     -> Result<Val, EvalError> {
        let rhs = stack.pop().ok_or(EvalError::Underflow { pc })?;
        let lhs = stack.pop().ok_or(EvalError::Underflow { pc })?;
        f(&lhs, &rhs).map_err(|err| EvalError::Type { pc, err })
    };
    match &prog.code[pc] {
        Instr::Const(v) => stack.push(v.clone()),
        Instr::Load(n) => stack.push(locals[*n as usize].clone()),
        Instr::Store(n) => {
            let v = pop(stack)?;
            locals[*n as usize] = v;
        }
        Instr::LoadCur => {
            let v = cur.expect("LoadCur outside loop rejected by verifier");
            stack.push(v.clone());
        }
        Instr::LoadKey => stack.push(ctx.key.clone()),
        Instr::ValuesLen => match ctx.fake_len {
            Some(n) => stack.push(Val::I64(n)),
            None => stack.push(Val::I64(ctx.values.len() as i64)),
        },
        Instr::ValuesFirst => match &ctx.fake_first {
            Some(v) => stack.push(v.clone()),
            None => {
                let v = ctx.values.first().ok_or(EvalError::BadIndex { pc })?;
                stack.push(v.clone());
            }
        },
        Instr::ValuesIndex => {
            let idx = pop(stack)?
                .as_i64()
                .ok_or(EvalError::BadIndex { pc })?;
            let v = ctx
                .values
                .get(idx as usize)
                .ok_or(EvalError::BadIndex { pc })?;
            stack.push(v.clone());
        }
        Instr::LoadExtern(slot) => {
            let v = ctx
                .externs
                .get(*slot as usize)
                .ok_or(EvalError::BadExtern { pc, slot: *slot })?;
            stack.push(v.clone());
        }
        Instr::Add => {
            let v = bin(stack, Val::add)?;
            stack.push(v);
        }
        Instr::Sub => {
            let v = bin(stack, Val::sub)?;
            stack.push(v);
        }
        Instr::Mul => {
            let v = bin(stack, Val::mul)?;
            stack.push(v);
        }
        Instr::Div => {
            let v = bin(stack, Val::div)?;
            stack.push(v);
        }
        Instr::Min => {
            let v = bin(stack, Val::min)?;
            stack.push(v);
        }
        Instr::Max => {
            let v = bin(stack, Val::max)?;
            stack.push(v);
        }
        Instr::Lt => {
            let rhs = pop(stack)?;
            let lhs = pop(stack)?;
            let r = match (lhs.as_f64(), rhs.as_f64()) {
                (Some(a), Some(b)) => Val::Bool(a < b),
                _ => {
                    return Err(EvalError::Type {
                        pc,
                        err: TypeError::Binary("lt", lhs.ty(), rhs.ty()),
                    })
                }
            };
            stack.push(r);
        }
        Instr::Select => {
            let cond = pop(stack)?;
            let else_v = pop(stack)?;
            let then_v = pop(stack)?;
            match cond {
                Val::Bool(true) => stack.push(then_v),
                Val::Bool(false) => stack.push(else_v),
                _ => return Err(EvalError::BadCondition { pc }),
            }
        }
        Instr::Dup => {
            let v = pop(stack)?;
            stack.push(v.clone());
            stack.push(v);
        }
        Instr::Pop => {
            pop(stack)?;
        }
        Instr::Swap => {
            let a = pop(stack)?;
            let b = pop(stack)?;
            stack.push(a);
            stack.push(b);
        }
        Instr::Emit => {
            let v = pop(stack)?;
            emit(v);
        }
        Instr::IterStart | Instr::IterEnd | Instr::BreakIf => {
            unreachable!("control handled by exec_range")
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::builder::canon;

    fn run(prog: &Program, values: &[Val]) -> Vec<Val> {
        let key = Val::Str("k".into());
        let externs = [Val::I64(1000)];
        let ctx = ReduceCtx::new(&key, values).with_externs(&externs);
        let mut out = Vec::new();
        run_reduce(prog, &ctx, |v| out.push(v)).unwrap();
        out
    }

    fn i64s(xs: &[i64]) -> Vec<Val> {
        xs.iter().map(|&x| Val::I64(x)).collect()
    }

    #[test]
    fn sum_reduces() {
        let out = run(&canon::sum_i64("s"), &i64s(&[1, 2, 3, 4]));
        assert_eq!(out, vec![Val::I64(10)]);
    }

    #[test]
    fn sum_of_empty_is_init() {
        let out = run(&canon::sum_i64("s"), &[]);
        assert_eq!(out, vec![Val::I64(0)]);
    }

    #[test]
    fn vector_sum_reduces() {
        let vals = vec![
            Val::F64Vec(vec![1.0, 2.0, 1.0]),
            Val::F64Vec(vec![3.0, 4.0, 1.0]),
        ];
        let out = run(&canon::sum_vec("v", 3), &vals);
        assert_eq!(out, vec![Val::F64Vec(vec![4.0, 6.0, 2.0])]);
    }

    #[test]
    fn min_max_reduce() {
        let out = run(
            &canon::min_f64("m"),
            &[Val::F64(3.0), Val::F64(-1.0), Val::F64(2.0)],
        );
        assert_eq!(out, vec![Val::F64(-1.0)]);
        let out = run(&canon::max_i64("m"), &i64s(&[3, 9, 2]));
        assert_eq!(out, vec![Val::I64(9)]);
    }

    #[test]
    fn count_and_first_idioms() {
        assert_eq!(run(&canon::count("c"), &i64s(&[5, 5, 5])), vec![Val::I64(3)]);
        assert_eq!(run(&canon::first("f"), &i64s(&[7, 8])), vec![Val::I64(7)]);
    }

    #[test]
    fn scaled_sum_finalizes() {
        let out = run(
            &canon::scaled_sum_f64("ss", 0.5),
            &[Val::F64(2.0), Val::F64(4.0)],
        );
        assert_eq!(out, vec![Val::F64(3.0)]);
    }

    #[test]
    fn early_exit_breaks() {
        // acc starts 0; condition `acc < 100` breaks immediately → emits 0.
        let out = run(&canon::early_exit("e"), &i64s(&[10, 20]));
        assert_eq!(out, vec![Val::I64(0)]);
    }

    #[test]
    fn extern_reads_environment() {
        let out = run(&canon::extern_seed("x"), &i64s(&[1, 2]));
        assert_eq!(out, vec![Val::I64(1003)]); // 1000 + 1 + 2
    }

    #[test]
    fn random_access_indexes() {
        let out = run(&canon::random_access("r"), &i64s(&[10, 20, 30]));
        assert_eq!(out, vec![Val::I64(20)]);
    }

    #[test]
    fn emit_in_loop_emits_per_value() {
        let out = run(&canon::emit_in_loop("e"), &i64s(&[4, 5]));
        assert_eq!(out, vec![Val::I64(4), Val::I64(5), Val::I64(0)]);
    }

    #[test]
    fn first_on_empty_errors() {
        let key = Val::Nil;
        let ctx = ReduceCtx::new(&key, &[]);
        let err = run_reduce(&canon::first("f"), &ctx, |_| {}).unwrap_err();
        assert!(matches!(err, EvalError::BadIndex { .. }));
    }

    #[test]
    fn slice_execution_runs_body_once() {
        let p = canon::sum_i64("s");
        let (lo, hi) = p.loop_span().unwrap();
        let mut locals = vec![Val::I64(10)];
        let key = Val::Nil;
        let ctx = ReduceCtx::new(&key, &[]);
        let emitted = run_slice(&p, lo + 1, hi, &mut locals, Some(&Val::I64(5)), &ctx).unwrap();
        assert_eq!(emitted, None);
        assert_eq!(locals[0], Val::I64(15));
    }
}
