//! The co-designed optimizer — paper §3.
//!
//! In the paper, user reducers are Java bytecode; a Java agent intercepts
//! class loading, parses the `reduce` method into a program dependency
//! graph, verifies two conditions (the loop covers *all* intermediate
//! values; the loop body depends only on the accumulator and the current
//! value), and rewrites the method into three generated methods —
//! `initialize` / `combine` / `finalize` — enabling a combining execution
//! flow that eliminates the reduce phase entirely.
//!
//! This module is the Rust rendering of that machinery. Bytecode becomes
//! **RIR** (Reducer Intermediate Representation), a small stack-machine IR
//! with an explicit values-loop construct ([`rir`]). The pipeline mirrors
//! the paper's steps 1–6 (§3.2):
//!
//! 1. [`pdg`] parses RIR into a program dependency graph;
//! 2. [`analyze`](mod@analyze) identifies the loop and checks coverage of all values;
//! 3. the initialization slice is checked for external data dependencies
//!    and its holder type inferred;
//! 4. the loop body is checked to depend only on {accumulator, current
//!    value} (associativity is assumed from MapReduce semantics, exactly
//!    as the paper does);
//! 5. the finalization slice is cut at the `Emit` call;
//! 6. [`transform`](mod@transform) packages the three slices as a [`combiner::Combiner`]
//!    and flips the flag that selects the combining execution flow.
//!
//! Idiomatic reducers that use only `values.len()` or `values[0]` are
//! handled directly ([`analyze`](mod@analyze) returns `Idiom::Count` / `Idiom::First`),
//! matching "two idiomatic reducers handled directly in code".
//!
//! [`agent`] is the Java-agent analogue: it intercepts every reducer
//! registration, runs detection + transformation, caches the result per
//! reducer class, and records the per-class timing the paper reports in
//! §4.3 (81 µs detection / 7.6 ms transformation). Since the lazy-plan
//! redesign it also runs a **whole-plan pass**
//! ([`agent::OptimizerAgent::plan`]): given a [`crate::api::plan::Dataset`]'s
//! logical stages, it decides element-wise fusion and reduce-handoff
//! streaming — the cross-stage placements a per-class view cannot see.

pub mod agent;
pub mod analyze;
pub mod ast;
pub mod builder;
pub mod combiner;
pub mod hints;
pub mod interp;
pub mod pdg;
pub mod rir;
pub mod transform;
pub mod value;

pub use agent::{AgentStats, OptimizerAgent};
pub use analyze::{analyze, Analysis, Idiom, Reject};
pub use combiner::Combiner;
pub use hints::{analyze_hints, Hint, Severity};
pub use rir::{Instr, Program};
pub use transform::transform;
pub use value::{RirValue, Ty, Val};
