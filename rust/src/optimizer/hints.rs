//! Safety-hint analysis — the paper's §3.1.1 extension realized:
//!
//! > "The implemented technique makes possible the potential analysis and
//! > implementation of verification code that provide hints at where
//! > violations to the safety of a MapReduce application lie."
//!
//! Where [`super::analyze`](mod@super::analyze) answers *can this reducer be combined?*, this
//! pass answers *is this reducer even a safe MapReduce reducer?* and, when
//! the answer is "probably not", points at the instruction responsible.
//! Hints are advisory (the framework still runs the program); the CLI's
//! `explain` command and the agent's diagnostics surface them.

use super::pdg::{build_region, Source};
use super::rir::{Instr, Program};

/// Severity of a hint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Stylistic / performance note.
    Info,
    /// Likely semantic hazard under MapReduce's execution freedoms.
    Warning,
    /// Violates MapReduce semantics outright.
    Error,
}

/// One diagnostic.
#[derive(Clone, Debug, PartialEq)]
pub struct Hint {
    pub severity: Severity,
    /// Program counter of the offending instruction (when attributable).
    pub pc: Option<usize>,
    pub message: String,
}

impl Hint {
    fn new(severity: Severity, pc: Option<usize>, message: impl Into<String>) -> Hint {
        Hint {
            severity,
            pc,
            message: message.into(),
        }
    }
}

/// Analyze a reducer program for MapReduce-safety hazards.
///
/// Checks (each keyed to the semantics the paper leans on):
/// 1. **Shared mutable state** — `LoadExtern` anywhere: the reducer reads
///    state outside the (key, values) contract; under parallel reduction
///    this must be thread-safe, which the framework cannot verify
///    ("should a value contain shared mutable state ... this must be
///    thread-safe for the reduce method to provide a correct answer").
/// 2. **Partial consumption** — `BreakIf`: the reducer may not see all
///    values; results then depend on value order, which MapReduce leaves
///    unspecified.
/// 3. **Order sensitivity** — non-commutative ops (`Sub`, `Div`) folding
///    `Cur` into an accumulator: correctness then depends on emit order
///    across map tasks.
/// 4. **Positional access** — `ValuesIndex`: value-list order is not part
///    of the MapReduce contract.
/// 5. **Per-value emission** — `Emit` inside the loop: legal, but the
///    output multiset then scales with value count (often a fan-out bug).
/// 6. **Key-dependent initialization** — init depending on `Key`:
///    combiner-hostile and usually a modeling smell.
pub fn analyze_hints(prog: &Program) -> Vec<Hint> {
    let mut hints = Vec::new();
    let loop_span = prog.loop_span();

    for (pc, ins) in prog.code.iter().enumerate() {
        match ins {
            Instr::LoadExtern(slot) => hints.push(Hint::new(
                Severity::Warning,
                Some(pc),
                format!(
                    "reads captured state (extern {slot}): must be immutable or thread-safe under parallel reduction"
                ),
            )),
            Instr::BreakIf => hints.push(Hint::new(
                Severity::Error,
                Some(pc),
                "early exit: not all intermediate values are consumed; result depends on unspecified value order",
            )),
            Instr::ValuesIndex => hints.push(Hint::new(
                Severity::Warning,
                Some(pc),
                "positional access values[i]: value order is not guaranteed by MapReduce",
            )),
            Instr::Emit => {
                if let Some((lo, hi)) = loop_span {
                    if pc > lo && pc < hi {
                        hints.push(Hint::new(
                            Severity::Info,
                            Some(pc),
                            "emit inside the values loop: output cardinality scales with value count (fan-out)",
                        ));
                    }
                }
            }
            _ => {}
        }
    }

    // Order sensitivity: inside the loop, a Sub/Div whose operands include
    // both an accumulator-carried value and Cur.
    if let Some((lo, hi)) = loop_span {
        if let Ok(pdg) = build_region(prog, lo + 1, hi) {
            for pc in lo + 1..hi {
                if !matches!(prog.code[pc], Instr::Sub | Instr::Div) {
                    continue;
                }
                let sources = pdg.sources(prog, pc);
                let carries = sources.iter().any(|s| matches!(s, Source::LocalIn(_)));
                let uses_cur = sources.contains(&Source::Cur);
                if carries && uses_cur {
                    hints.push(Hint::new(
                        Severity::Warning,
                        Some(pc),
                        format!(
                            "`{}` folds the current value non-commutatively: result depends on emit order across map tasks",
                            prog.code[pc].mnemonic()
                        ),
                    ));
                }
            }
        }
        // Key-dependent init.
        if let Ok(pdg) = build_region(prog, 0, lo) {
            for pc in 0..lo {
                if matches!(prog.code[pc], Instr::Store(_))
                    && pdg.sources(prog, pc).contains(&Source::Key)
                {
                    hints.push(Hint::new(
                        Severity::Info,
                        Some(pc),
                        "accumulator initialized from the key: prevents combining and is usually a modeling smell",
                    ));
                }
            }
        }
    }

    hints.sort_by(|a, b| b.severity.cmp(&a.severity).then(a.pc.cmp(&b.pc)));
    hints
}

/// Render hints for the CLI.
pub fn render_hints(hints: &[Hint]) -> String {
    if hints.is_empty() {
        return "no safety hints — reducer is a clean fold\n".to_string();
    }
    let mut out = String::new();
    for h in hints {
        let sev = match h.severity {
            Severity::Error => "ERROR",
            Severity::Warning => "WARN ",
            Severity::Info => "info ",
        };
        let at = h.pc.map(|pc| format!(" @pc {pc}")).unwrap_or_default();
        out.push_str(&format!("{sev}{at}: {}\n", h.message));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::builder::{canon, ProgramBuilder};

    #[test]
    fn clean_fold_has_no_hints() {
        assert!(analyze_hints(&canon::sum_i64("s")).is_empty());
        assert!(analyze_hints(&canon::count("c")).is_empty());
    }

    #[test]
    fn early_exit_is_an_error() {
        let hints = analyze_hints(&canon::early_exit("e"));
        assert!(hints.iter().any(|h| h.severity == Severity::Error));
    }

    #[test]
    fn extern_is_a_warning_with_location() {
        let hints = analyze_hints(&canon::extern_seed("x"));
        let h = hints
            .iter()
            .find(|h| h.message.contains("captured state"))
            .expect("extern hint");
        assert_eq!(h.severity, Severity::Warning);
        assert_eq!(h.pc, Some(0));
    }

    #[test]
    fn order_sensitive_sub_flagged() {
        // acc = acc - cur : order-dependent across map tasks.
        let p = ProgramBuilder::new("sub")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .sub()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        let hints = analyze_hints(&p);
        assert!(
            hints.iter().any(|h| h.message.contains("non-commutatively")),
            "{hints:?}"
        );
    }

    #[test]
    fn cur_minus_cur_not_flagged() {
        // acc = acc + (cur - cur*1) : the Sub has no accumulator carry.
        let p = ProgramBuilder::new("cc")
            .const_i64(0)
            .store(0)
            .iter_start()
            .load(0)
            .load_cur()
            .load_cur()
            .sub()
            .add()
            .store(0)
            .iter_end()
            .load(0)
            .emit()
            .build()
            .unwrap();
        let hints = analyze_hints(&p);
        assert!(
            !hints.iter().any(|h| h.message.contains("non-commutatively")),
            "{hints:?}"
        );
    }

    #[test]
    fn emit_in_loop_is_info() {
        let hints = analyze_hints(&canon::emit_in_loop("e"));
        assert!(hints
            .iter()
            .any(|h| h.severity == Severity::Info && h.message.contains("fan-out")));
    }

    #[test]
    fn rendering_orders_by_severity() {
        let hints = analyze_hints(&canon::early_exit("e"));
        let text = render_hints(&hints);
        assert!(text.starts_with("ERROR"));
        assert!(render_hints(&[]).contains("clean fold"));
    }
}
