//! Step 6 of the paper's pipeline: package the verified slices as a
//! combiner and (where the body matches a recognized shape) attach the
//! compiled fast path.

use std::sync::Arc;

use super::analyze::Analysis;
use super::combiner::{detect_fast_path, Combiner};
use super::rir::Program;

/// Build the combiner for an accepted analysis. Infallible by construction:
/// `analyze` has already proven the slices well-formed.
pub fn transform(program: Arc<Program>, analysis: Analysis) -> Combiner {
    let fast = detect_fast_path(&program, &analysis);
    Combiner::new(program, analysis, fast)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::analyze::analyze;
    use crate::optimizer::builder::canon;
    use crate::optimizer::combiner::FastPath;

    #[test]
    fn canonical_fast_paths() {
        let cases: Vec<(Program, Option<FastPath>)> = vec![
            (canon::sum_i64("a"), Some(FastPath::AddI64)),
            (canon::sum_f64("b"), Some(FastPath::AddF64)),
            (canon::sum_vec("c", 4), Some(FastPath::AddVec)),
            (canon::min_f64("d"), Some(FastPath::MinF64)),
            (canon::max_i64("e"), Some(FastPath::MaxI64)),
            (canon::count("f"), Some(FastPath::Count)),
            (canon::first("g"), Some(FastPath::First)),
            (canon::scaled_sum_f64("h", 2.0), None),
        ];
        for (p, expect) in cases {
            let name = p.name.clone();
            let a = analyze(&p).unwrap();
            let c = transform(Arc::new(p), a);
            assert_eq!(c.fast_path(), expect, "{name}");
        }
    }
}
