//! Program dependency graph over RIR (paper §3.2 step 1: "Parse the reduce
//! method to create an intermediate representation of the code in a program
//! dependency graph").
//!
//! Built by abstract interpretation of the stack: each instruction becomes a
//! node; data edges point from the producers of an instruction's operands
//! (stack edges) and from the reaching `Store` of each `Load` (local edges).
//! The analyzer then asks *transitive source* questions: "does anything the
//! init block stores depend on an external value?", "does the loop body read
//! anything besides the accumulator and the current value?".

use super::rir::{Instr, Program};

/// Primitive value sources an instruction may transitively depend on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Source {
    Const,
    /// The current loop value.
    Cur,
    /// The reduce key.
    Key,
    /// `values.len()`.
    Len,
    /// `values[0]`.
    First,
    /// `values[i]` random access.
    Index,
    /// Captured environment (external data dependency).
    Extern,
    /// A local whose defining store lies *outside* the analyzed region
    /// (i.e. loop-carried or init-provided state).
    LocalIn(u8),
}

/// The dependency graph.
#[derive(Clone, Debug)]
pub struct Pdg {
    /// For each pc: the pcs that produced its stack operands.
    pub operand_producers: Vec<Vec<usize>>,
    /// For each pc that is a `Load`, the pc of the reaching `Store` (None =
    /// defined before the program / outside the region).
    pub reaching_store: Vec<Option<usize>>,
}

/// Errors only malformed (unverified) programs can produce.
#[derive(Clone, Debug, PartialEq)]
pub enum PdgError {
    Underflow(usize),
}

impl std::fmt::Display for PdgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PdgError::Underflow(pc) => {
                write!(f, "stack underflow during abstract interpretation at pc {pc}")
            }
        }
    }
}

impl std::error::Error for PdgError {}

/// Build the PDG for a straight-line region `[lo, hi)` of `prog`
/// (loop markers inside are skipped as no-ops; the analyzer calls this per
/// region so cross-region flow shows up as `LocalIn` sources).
pub fn build_region(prog: &Program, lo: usize, hi: usize) -> Result<Pdg, PdgError> {
    let n = prog.code.len();
    let mut operand_producers = vec![Vec::new(); n];
    let mut reaching_store: Vec<Option<usize>> = vec![None; n];
    // Abstract stack of producer pcs.
    let mut stack: Vec<usize> = Vec::new();
    // Last store to each local within the region.
    let mut last_store: Vec<Option<usize>> = vec![None; prog.n_locals as usize];

    for pc in lo..hi {
        let ins = &prog.code[pc];
        if matches!(ins, Instr::IterStart | Instr::IterEnd) {
            continue;
        }
        let (pops, pushes) = ins
            .stack_effect()
            .expect("loop markers handled above");
        if stack.len() < pops {
            return Err(PdgError::Underflow(pc));
        }
        let operands: Vec<usize> = stack.split_off(stack.len() - pops);
        // Record local def-use before updating defs.
        match ins {
            Instr::Load(l) => reaching_store[pc] = last_store[*l as usize],
            Instr::Store(l) => last_store[*l as usize] = Some(pc),
            _ => {}
        }
        operand_producers[pc] = operands;
        for _ in 0..pushes {
            stack.push(pc);
        }
    }
    Ok(Pdg {
        operand_producers,
        reaching_store,
    })
}

impl Pdg {
    /// Transitive primitive sources of the value(s) consumed/produced at
    /// `pc`, restricted to the region the PDG was built over.
    pub fn sources(&self, prog: &Program, pc: usize) -> Vec<Source> {
        let mut out = Vec::new();
        let mut seen = vec![false; prog.code.len()];
        self.collect(prog, pc, &mut seen, &mut out);
        out.sort_by_key(|s| format!("{s:?}"));
        out.dedup();
        out
    }

    fn collect(&self, prog: &Program, pc: usize, seen: &mut [bool], out: &mut Vec<Source>) {
        if seen[pc] {
            return;
        }
        seen[pc] = true;
        match &prog.code[pc] {
            Instr::Const(_) => out.push(Source::Const),
            Instr::LoadCur => out.push(Source::Cur),
            Instr::LoadKey => out.push(Source::Key),
            Instr::ValuesLen => out.push(Source::Len),
            Instr::ValuesFirst => out.push(Source::First),
            Instr::ValuesIndex => out.push(Source::Index),
            Instr::LoadExtern(_) => out.push(Source::Extern),
            Instr::Load(l) => match self.reaching_store[pc] {
                Some(def) => self.collect(prog, def, seen, out),
                None => out.push(Source::LocalIn(*l)),
            },
            _ => {}
        }
        for &p in &self.operand_producers[pc] {
            self.collect(prog, p, seen, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::builder::{canon, ProgramBuilder};
    use crate::optimizer::value::Val;

    #[test]
    fn sum_body_sources_are_acc_and_cur() {
        let p = canon::sum_i64("s");
        let (lo, hi) = p.loop_span().unwrap();
        let pdg = build_region(&p, lo + 1, hi).unwrap();
        // The Store closing the loop body.
        let store_pc = (lo + 1..hi)
            .find(|&pc| matches!(p.code[pc], Instr::Store(_)))
            .unwrap();
        let src = pdg.sources(&p, store_pc);
        assert!(src.contains(&Source::Cur));
        assert!(src.contains(&Source::LocalIn(0)), "accumulator flows in: {src:?}");
        assert!(!src.contains(&Source::Extern));
    }

    #[test]
    fn extern_seed_init_is_flagged() {
        let p = canon::extern_seed("x");
        let (lo, _) = p.loop_span().unwrap();
        let pdg = build_region(&p, 0, lo).unwrap();
        let store_pc = (0..lo)
            .find(|&pc| matches!(p.code[pc], Instr::Store(_)))
            .unwrap();
        assert!(pdg.sources(&p, store_pc).contains(&Source::Extern));
    }

    #[test]
    fn const_init_is_clean() {
        let p = canon::sum_i64("s");
        let (lo, _) = p.loop_span().unwrap();
        let pdg = build_region(&p, 0, lo).unwrap();
        let store_pc = (0..lo)
            .find(|&pc| matches!(p.code[pc], Instr::Store(_)))
            .unwrap();
        assert_eq!(pdg.sources(&p, store_pc), vec![Source::Const]);
    }

    #[test]
    fn dup_and_swap_preserve_provenance() {
        // key → dup → swap → add: both operands trace to Key.
        let p = ProgramBuilder::new("t")
            .load_key()
            .dup()
            .swap()
            .add()
            .emit()
            .build_unchecked();
        let pdg = build_region(&p, 0, p.code.len()).unwrap();
        let add_pc = 3;
        assert_eq!(pdg.sources(&p, add_pc), vec![Source::Key]);
    }

    #[test]
    fn values_len_traced_through_arithmetic() {
        let p = ProgramBuilder::new("t")
            .values_len()
            .const_val(Val::I64(2))
            .mul()
            .emit()
            .build()
            .unwrap();
        let pdg = build_region(&p, 0, p.code.len()).unwrap();
        let emit_pc = p.code.len() - 1;
        let src = pdg.sources(&p, emit_pc);
        assert!(src.contains(&Source::Len));
        assert!(src.contains(&Source::Const));
    }
}
