//! Runtime values and static types for RIR programs.
//!
//! The JVM optimizer works over boxed Java values (with a mutable Holder
//! class generated per type); RIR works over [`Val`]. The set covers every
//! value type the benchmark suite emits: counts (`I64`), measures (`F64`),
//! coordinate accumulators (`F64Vec`, used by K-Means running sums), and
//! strings (Word Count keys when values round-trip through the IR).

use crate::api::traits::HeapSized;

/// A dynamically-typed RIR value.
#[derive(Clone, Debug, PartialEq)]
pub enum Val {
    /// Absent value — the pre-first-combine state of `First` holders.
    Nil,
    Bool(bool),
    I64(i64),
    F64(f64),
    F64Vec(Vec<f64>),
    Str(String),
}

/// Static type of a [`Val`] (holder type inference, paper §3.1.1's
/// "determine the holder type required").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ty {
    Nil,
    Bool,
    I64,
    F64,
    F64Vec,
    Str,
}

impl Val {
    pub fn ty(&self) -> Ty {
        match self {
            Val::Nil => Ty::Nil,
            Val::Bool(_) => Ty::Bool,
            Val::I64(_) => Ty::I64,
            Val::F64(_) => Ty::F64,
            Val::F64Vec(_) => Ty::F64Vec,
            Val::Str(_) => Ty::Str,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Val::I64(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Val::F64(x) => Some(*x),
            Val::I64(x) => Some(*x as f64),
            _ => None,
        }
    }

    /// Numeric/vector addition (the workhorse of combiners).
    pub fn add(&self, rhs: &Val) -> Result<Val, TypeError> {
        match (self, rhs) {
            (Val::I64(a), Val::I64(b)) => Ok(Val::I64(a.wrapping_add(*b))),
            (Val::F64(a), Val::F64(b)) => Ok(Val::F64(a + b)),
            (Val::I64(a), Val::F64(b)) | (Val::F64(b), Val::I64(a)) => {
                Ok(Val::F64(*a as f64 + b))
            }
            (Val::F64Vec(a), Val::F64Vec(b)) => {
                if a.len() != b.len() {
                    return Err(TypeError::VecLen(a.len(), b.len()));
                }
                Ok(Val::F64Vec(a.iter().zip(b).map(|(x, y)| x + y).collect()))
            }
            (a, b) => Err(TypeError::Binary("add", a.ty(), b.ty())),
        }
    }

    pub fn sub(&self, rhs: &Val) -> Result<Val, TypeError> {
        match (self, rhs) {
            (Val::I64(a), Val::I64(b)) => Ok(Val::I64(a.wrapping_sub(*b))),
            (Val::F64(a), Val::F64(b)) => Ok(Val::F64(a - b)),
            (a, b) => Err(TypeError::Binary("sub", a.ty(), b.ty())),
        }
    }

    pub fn mul(&self, rhs: &Val) -> Result<Val, TypeError> {
        match (self, rhs) {
            (Val::I64(a), Val::I64(b)) => Ok(Val::I64(a.wrapping_mul(*b))),
            (Val::F64(a), Val::F64(b)) => Ok(Val::F64(a * b)),
            (Val::F64Vec(a), Val::F64(s)) => {
                Ok(Val::F64Vec(a.iter().map(|x| x * s).collect()))
            }
            (a, b) => Err(TypeError::Binary("mul", a.ty(), b.ty())),
        }
    }

    pub fn div(&self, rhs: &Val) -> Result<Val, TypeError> {
        match (self, rhs) {
            (Val::I64(a), Val::I64(b)) if *b != 0 => Ok(Val::I64(a / b)),
            (Val::I64(_), Val::I64(_)) => Err(TypeError::DivZero),
            (Val::F64(a), Val::F64(b)) => Ok(Val::F64(a / b)),
            (Val::F64Vec(a), Val::F64(s)) => {
                Ok(Val::F64Vec(a.iter().map(|x| x / s).collect()))
            }
            (a, b) => Err(TypeError::Binary("div", a.ty(), b.ty())),
        }
    }

    pub fn min(&self, rhs: &Val) -> Result<Val, TypeError> {
        match (self, rhs) {
            (Val::I64(a), Val::I64(b)) => Ok(Val::I64((*a).min(*b))),
            (Val::F64(a), Val::F64(b)) => Ok(Val::F64(a.min(*b))),
            (a, b) => Err(TypeError::Binary("min", a.ty(), b.ty())),
        }
    }

    pub fn max(&self, rhs: &Val) -> Result<Val, TypeError> {
        match (self, rhs) {
            (Val::I64(a), Val::I64(b)) => Ok(Val::I64((*a).max(*b))),
            (Val::F64(a), Val::F64(b)) => Ok(Val::F64(a.max(*b))),
            (a, b) => Err(TypeError::Binary("max", a.ty(), b.ty())),
        }
    }
}

impl HeapSized for Val {
    fn heap_bytes(&self) -> u64 {
        match self {
            Val::Nil | Val::Bool(_) => 16,
            Val::I64(_) | Val::F64(_) => 16,
            Val::F64Vec(v) => 24 + 8 * v.len() as u64,
            Val::Str(s) => 40 + s.len() as u64,
        }
    }
}

/// Type errors surfaced by RIR evaluation.
#[derive(Clone, Debug, PartialEq)]
pub enum TypeError {
    Binary(&'static str, Ty, Ty),
    VecLen(usize, usize),
    DivZero,
    Expected(Ty, Ty),
    Underflow,
}

impl std::fmt::Display for TypeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TypeError::Binary(op, a, b) => write!(f, "`{op}` not defined for ({a:?}, {b:?})"),
            TypeError::VecLen(a, b) => write!(f, "vector length mismatch: {a} vs {b}"),
            TypeError::DivZero => write!(f, "integer division by zero"),
            TypeError::Expected(want, got) => write!(f, "expected {want:?}, found {got:?}"),
            TypeError::Underflow => write!(f, "stack underflow"),
        }
    }
}

impl std::error::Error for TypeError {}

/// User value types convertible to and from [`Val`] — the bound the
/// combining flow needs on `V`. This plays the role of Java's boxing: the
/// framework can lift any such value into the IR's domain and back.
pub trait RirValue: Clone + Send + Sync + HeapSized + 'static {
    fn to_val(&self) -> Val;
    fn from_val(v: Val) -> Option<Self>;

    /// Move-lift into the IR domain. Override when the value owns heap
    /// payload (`Vec<f64>`, `String`) to avoid the per-emit clone on the
    /// combine-flow hot path.
    fn into_val(self) -> Val
    where
        Self: Sized,
    {
        self.to_val()
    }
}

impl RirValue for i64 {
    fn to_val(&self) -> Val {
        Val::I64(*self)
    }
    fn from_val(v: Val) -> Option<Self> {
        v.as_i64()
    }
}

impl RirValue for f64 {
    fn to_val(&self) -> Val {
        Val::F64(*self)
    }
    fn from_val(v: Val) -> Option<Self> {
        match v {
            Val::F64(x) => Some(x),
            Val::I64(x) => Some(x as f64),
            _ => None,
        }
    }
}

impl RirValue for Vec<f64> {
    fn to_val(&self) -> Val {
        Val::F64Vec(self.clone())
    }
    fn from_val(v: Val) -> Option<Self> {
        match v {
            Val::F64Vec(x) => Some(x),
            _ => None,
        }
    }
    fn into_val(self) -> Val {
        Val::F64Vec(self)
    }
}

impl RirValue for String {
    fn to_val(&self) -> Val {
        Val::Str(self.clone())
    }
    fn from_val(v: Val) -> Option<Self> {
        match v {
            Val::Str(s) => Some(s),
            _ => None,
        }
    }
    fn into_val(self) -> Val {
        Val::Str(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_dispatch() {
        assert_eq!(Val::I64(2).add(&Val::I64(3)).unwrap(), Val::I64(5));
        assert_eq!(Val::F64(0.5).add(&Val::F64(1.0)).unwrap(), Val::F64(1.5));
        assert_eq!(
            Val::F64Vec(vec![1.0, 2.0])
                .add(&Val::F64Vec(vec![3.0, 4.0]))
                .unwrap(),
            Val::F64Vec(vec![4.0, 6.0])
        );
        assert_eq!(Val::I64(7).max(&Val::I64(3)).unwrap(), Val::I64(7));
    }

    #[test]
    fn type_errors_are_reported() {
        assert!(matches!(
            Val::Str("x".into()).add(&Val::I64(1)),
            Err(TypeError::Binary("add", Ty::Str, Ty::I64))
        ));
        assert_eq!(Val::I64(1).div(&Val::I64(0)), Err(TypeError::DivZero));
        assert!(Val::F64Vec(vec![1.0]).add(&Val::F64Vec(vec![1.0, 2.0])).is_err());
    }

    #[test]
    fn rir_value_roundtrip() {
        assert_eq!(i64::from_val(42i64.to_val()), Some(42));
        assert_eq!(f64::from_val(2.5f64.to_val()), Some(2.5));
        let v = vec![1.0, 2.0];
        assert_eq!(Vec::<f64>::from_val(v.to_val()), Some(v));
        assert_eq!(String::from_val("hi".to_string().to_val()), Some("hi".into()));
        assert_eq!(i64::from_val(Val::Str("no".into())), None);
    }

    #[test]
    fn heap_bytes_by_shape() {
        assert_eq!(Val::I64(1).heap_bytes(), 16);
        assert_eq!(Val::F64Vec(vec![0.0; 4]).heap_bytes(), 24 + 32);
    }
}
