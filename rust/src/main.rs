//! The `mr4r` launcher.
//!
//! ```text
//! mr4r figures <fig5|fig6|fig7|fig8|fig9|fig10|table1|table2|overhead|all>
//!      [--scale S] [--seed N] [--iters N] [--warmup N] [--threads N]
//!      [--backend auto|native|pjrt] [--out DIR]
//! mr4r run --bench WC [--threads N] [--no-optimize] [--scale S]
//! mr4r explain --bench WC          # show the reducer RIR + agent decision
//! mr4r info                        # environment, artifacts, backend probe
//! mr4r govern [--tenants N] [--plans N] [--threads N] [--json]
//!                                  # multi-tenant QoS demo + live scoreboard
//! mr4r trace WC [--scale S] [--threads N] [--out DIR]
//!                                  # run once with the session tracer on and
//!                                  # write a Chrome trace_event JSON timeline
//! ```

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use mr4r::api::config::{JobConfig, OptimizeMode};
use mr4r::api::reducers::RirReducer;
use mr4r::api::runtime::Runtime;
use mr4r::api::traits::Emitter;
use mr4r::benchmarks::suite::{prepare, prepare_on, BenchId, Framework, RunParams};
use mr4r::benchmarks::Backend;
use mr4r::govern::{Priority, TenantSpec};
use mr4r::harness::{self, HarnessOpts};
use mr4r::optimizer::agent::{Decision, OptimizerAgent};
use mr4r::optimizer::builder::canon;
use mr4r::runtime::artifacts::KernelSet;
use mr4r::util::cli::{Cli, CliError};

fn cli() -> Cli {
    Cli::new("mr4r", "MR4R — co-designed MapReduce runtime (paper reproduction)")
        .opt("scale", "0.004", "input scale relative to the paper's datasets")
        .opt("seed", "42", "dataset seed")
        .opt("iters", "3", "measured iterations per data point")
        .opt("warmup", "1", "warm-up iterations (discarded)")
        .opt_no_default("threads", "max worker threads (default: max(cores, 8))")
        .opt("backend", "auto", "numeric backend: auto | native | pjrt")
        .opt("out", "reports", "report output directory")
        .opt_no_default("bench", "benchmark code: HG KM LR MM PC SM WC")
        .opt("tenants", "6", "tenant count for `govern`")
        .opt("plans", "2", "word-count plans per tenant for `govern`")
        .switch("no-optimize", "disable the reducer optimizer")
        .switch("json", "emit the `govern` scoreboard as JSON")
        .switch("quiet", "suppress per-report console output")
}

fn backend_from(arg: &str) -> Result<Backend, String> {
    match arg {
        "native" => Ok(Backend::Native),
        "pjrt" => KernelSet::try_load()
            .map(Backend::Pjrt)
            .ok_or_else(|| "artifacts missing: run `make artifacts` first".to_string()),
        "auto" => Ok(Backend::auto()),
        other => Err(format!("unknown backend `{other}`")),
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli().parse(&argv) {
        Ok(a) => a,
        Err(CliError::Help(h)) => {
            println!("{h}");
            return ExitCode::SUCCESS;
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli().help_text());
            return ExitCode::FAILURE;
        }
    };

    let command = args.positional().first().map(String::as_str).unwrap_or("");
    let target = args.positional().get(1).map(String::as_str).unwrap_or("");

    let opts = HarnessOpts {
        scale: args.parse_or("scale", 0.004),
        seed: args.parse_or("seed", 42),
        iters: args.parse_or("iters", 3),
        warmup: args.parse_or("warmup", 1),
        max_threads: args.parse_or(
            "threads",
            // Worker threads are a framework dimension (paper: 8/64), not
            // a host core count — default to ≥8 even on small hosts.
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .max(8),
        ),
    };
    let backend = match backend_from(args.get("backend").unwrap_or("auto")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let out_dir = PathBuf::from(args.get("out").unwrap_or("reports"));

    match command {
        "figures" => {
            let reports = match target {
                "all" | "" => harness::run_all(&opts, &backend),
                "table1" => vec![harness::table1::run(&opts)],
                "table2" => vec![harness::table2::run(&opts, &backend)],
                "fig5" => vec![harness::fig5::run(&opts, &backend)],
                "fig6" => vec![harness::fig6::run(&opts, &backend)],
                "fig7" => vec![harness::fig7::run(&opts, &backend)],
                "fig8" => vec![harness::fig89::run(&opts, &backend, false)],
                "fig9" => vec![harness::fig89::run(&opts, &backend, true)],
                "fig10" => vec![harness::fig10::run(&opts, &backend)],
                "overhead" => vec![harness::overhead::run(&opts)],
                other => {
                    eprintln!("unknown figure `{other}`");
                    return ExitCode::FAILURE;
                }
            };
            for r in &reports {
                if !args.flag("quiet") {
                    println!("{}", r.render());
                }
                if let Err(e) = r.write_to(&out_dir) {
                    eprintln!("error writing report {}: {e}", r.id);
                    return ExitCode::FAILURE;
                }
            }
            println!("wrote {} report(s) to {}", reports.len(), out_dir.display());
            ExitCode::SUCCESS
        }
        "run" => {
            let Some(id) = args.get("bench").and_then(BenchId::from_code) else {
                eprintln!("`run` needs --bench <HG|KM|LR|MM|PC|SM|WC>");
                return ExitCode::FAILURE;
            };
            let w = prepare(id, opts.scale, opts.seed, backend.clone());
            let mode = if args.flag("no-optimize") {
                OptimizeMode::Off
            } else {
                OptimizeMode::Auto
            };
            let heap = harness::scaled_heap(opts.scale, mr4r::memsim::GcPolicy::Parallel, 1.0);
            let params = RunParams::fast(opts.max_threads)
                .with_optimize(mode)
                .with_heap(heap.clone());
            let o = w.run(Framework::Mr4r, &params);
            let m = o.metrics.expect("mr4r metrics");
            println!("{} ({}), backend={}", id.code(), id.name(), backend.name());
            println!("  flow        : {}", m.flow.label());
            if let Some(r) = &m.fallback_reason {
                println!("  fallback    : {r}");
            }
            println!(
                "  total       : {:.3}s (map {:.3}s, reduce/finalize {:.3}s)",
                o.secs, m.map_secs, m.reduce_secs
            );
            println!("  emits/keys  : {} / {}", m.emits, m.keys);
            println!(
                "  gc          : {} minor, {} major, {:.3}s ({:.1}%)",
                m.gc.minor_collections,
                m.gc.major_collections,
                m.gc.gc_seconds,
                100.0 * m.gc.gc_seconds / o.secs.max(1e-9)
            );
            match &m.cache {
                Some(c) => println!(
                    "  cache       : {} hit(s), {} miss(es), {} shared in-flight, {} evicted, {} reload(s) ({} B), {} B inserted",
                    c.hits, c.misses, c.shared_in_flight, c.evictions, c.reloads, c.reload_bytes, c.bytes_inserted
                ),
                None => println!(
                    "  cache       : off (figure runs measure uncached execution; \
                     see Dataset::cache)"
                ),
            }
            println!("  digest      : {:016x}", o.digest);
            ExitCode::SUCCESS
        }
        "explain" => {
            let Some(id) = args.get("bench").and_then(BenchId::from_code) else {
                eprintln!("`explain` needs --bench <HG|KM|LR|MM|PC|SM|WC>");
                return ExitCode::FAILURE;
            };
            let program = match id {
                BenchId::WC => mr4r::optimizer::builder::canon::sum_i64("wordcount.sum"),
                BenchId::HG => mr4r::optimizer::builder::canon::sum_i64("histogram.sum"),
                BenchId::LR => mr4r::optimizer::builder::canon::sum_f64("linreg.sum"),
                BenchId::MM => mr4r::optimizer::builder::canon::sum_f64("matmul.sum"),
                BenchId::KM => mr4r::optimizer::builder::canon::sum_vec("kmeans.sumvec", 4),
                BenchId::PC => mr4r::optimizer::builder::canon::sum_vec("pca.sumvec", 3),
                BenchId::SM => mr4r::optimizer::builder::canon::count("stringmatch.count"),
            };
            println!("{}", program.disassemble());
            println!(
                "safety hints:\n{}",
                mr4r::optimizer::hints::render_hints(&mr4r::optimizer::hints::analyze_hints(
                    &program
                ))
            );
            let agent = OptimizerAgent::new();
            match agent.process(&program) {
                Decision::Combine(c) => {
                    println!(
                        "decision: COMBINE (idiom {:?}, fast path {:?})",
                        c.idiom(),
                        c.fast_path()
                    );
                    println!(
                        "holder: {:?} ({} bytes simulated)",
                        c.initialize(),
                        c.holder_bytes()
                    );
                }
                Decision::Fallback(r) => println!("decision: FALLBACK — {r}"),
                Decision::Opaque => println!("decision: OPAQUE"),
            }
            let s = agent.stats();
            println!(
                "detection {:.1}us, transformation {:.1}us",
                s.detection.mean() * 1e6,
                s.transformation.mean() * 1e6
            );
            ExitCode::SUCCESS
        }
        "info" => {
            println!(
                "mr4r {} — three-layer reproduction of Barrett et al. 2016",
                env!("CARGO_PKG_VERSION")
            );
            println!("host threads : {}", opts.max_threads);
            match KernelSet::try_load() {
                Some(ks) => println!(
                    "artifacts    : loaded ({} kernels, platform {})",
                    mr4r::runtime::KERNEL_NAMES.len(),
                    ks.platform()
                ),
                None => {
                    println!("artifacts    : NOT built (run `make artifacts`; native backend only)")
                }
            }
            println!("backend      : {}", backend.name());
            ExitCode::SUCCESS
        }
        "govern" => {
            let n_tenants: usize = args.parse_or("tenants", 6);
            let n_plans: usize = args.parse_or("plans", 2);
            let rt = Arc::new(Runtime::with_config(
                JobConfig::new().with_threads(opts.max_threads),
            ));
            let classes = [Priority::Interactive, Priority::Batch, Priority::Background];
            let handles: Vec<_> = (0..n_tenants)
                .map(|i| {
                    let spec = TenantSpec::new(&format!("tenant-{i:02}"))
                        .with_priority(classes[i % classes.len()]);
                    let id = rt.register_tenant(spec);
                    let seed = opts.seed.wrapping_add(i as u64);
                    Arc::clone(&rt).spawn_plan(move |rt| {
                        let cfg = rt.config_for(id);
                        let lines = demo_lines(seed);
                        let mut keys = 0;
                        for _ in 0..n_plans {
                            let out = rt
                                .job(
                                    wc_mapper,
                                    RirReducer::<String, i64>::new(canon::sum_i64("govern.wc")),
                                )
                                .with_config(cfg.clone())
                                .run(&lines);
                            keys = out.pairs.len();
                        }
                        keys
                    })
                })
                .collect();
            let keys: Vec<usize> = handles.into_iter().map(|h| h.join()).collect();
            if args.flag("json") {
                println!("{}", rt.scoreboard().snapshot_json().pretty());
                return ExitCode::SUCCESS;
            }
            println!(
                "{} tenant(s) x {} word-count plan(s) each, {} distinct key(s) per plan",
                n_tenants,
                n_plans,
                keys.first().copied().unwrap_or(0)
            );
            println!("{}", rt.scoreboard().render());
            ExitCode::SUCCESS
        }
        "trace" => {
            // Accept the bench positionally (`mr4r trace wc`) or via --bench.
            let code = if target.is_empty() {
                args.get("bench").unwrap_or("")
            } else {
                target
            };
            let Some(id) = BenchId::from_code(code) else {
                eprintln!("`trace` needs a benchmark code: mr4r trace <HG|KM|LR|MM|PC|SM|WC>");
                return ExitCode::FAILURE;
            };
            let mode = if args.flag("no-optimize") {
                OptimizeMode::Off
            } else {
                OptimizeMode::Auto
            };
            // An accounting heap (not `fast`) so the timeline includes the
            // memsim's cohort and GC events, and the runtime's own heap in
            // the run params so those events land on the session tracer.
            let rt = Arc::new(Runtime::with_config(
                JobConfig::new().with_threads(opts.max_threads),
            ));
            rt.tracer().set_enabled(true);
            let params = RunParams::fast(opts.max_threads)
                .with_optimize(mode)
                .with_heap(Arc::clone(rt.heap()));
            let w = prepare_on(Arc::clone(&rt), id, opts.scale, opts.seed, backend.clone());
            let o = w.run(Framework::Mr4r, &params);
            let events = rt.tracer().total_events();
            if events == 0 {
                eprintln!("error: traced run recorded no events");
                return ExitCode::FAILURE;
            }
            let trace = rt.tracer().export_chrome_trace();
            if let Err(e) = std::fs::create_dir_all(&out_dir) {
                eprintln!("error creating {}: {e}", out_dir.display());
                return ExitCode::FAILURE;
            }
            let path = out_dir.join(format!("{}.trace.json", id.code().to_lowercase()));
            if let Err(e) = std::fs::write(&path, trace.to_string()) {
                eprintln!("error writing {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
            println!(
                "{} ({}): {} trace event(s), {} dropped, digest {:016x}",
                id.code(),
                id.name(),
                events,
                rt.tracer().dropped(),
                o.digest
            );
            println!(
                "wrote {} — load it in chrome://tracing or https://ui.perfetto.dev",
                path.display()
            );
            ExitCode::SUCCESS
        }
        "" => {
            eprintln!("{}", cli().help_text());
            eprintln!("commands: figures | run | explain | info | govern | trace");
            ExitCode::FAILURE
        }
        other => {
            eprintln!(
                "unknown command `{other}` (try: figures, run, explain, info, govern, trace)"
            );
            ExitCode::FAILURE
        }
    }
}

/// Deterministic word-count input for the `govern` demo — each tenant
/// folds its seed into the line mix so concurrent plans differ without
/// any runtime randomness.
fn demo_lines(seed: u64) -> Vec<String> {
    const WORDS: [&str; 8] = ["the", "quick", "brown", "fox", "jumps", "over", "lazy", "dog"];
    (0..256u64)
        .map(|i| {
            let a = WORDS[(seed.wrapping_add(i) % 8) as usize];
            let b = WORDS[(seed.wrapping_mul(31).wrapping_add(i * 7) % 8) as usize];
            format!("{a} {b} the end")
        })
        .collect()
}

fn wc_mapper(line: &String, em: &mut dyn Emitter<String, i64>) {
    for w in line.split_whitespace() {
        em.emit(w.to_string(), 1);
    }
}
