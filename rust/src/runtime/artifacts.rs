//! The artifact store: named kernels with fixed AOT shapes.
//!
//! Shapes are the contract between `python/compile/aot.py` (which lowers
//! with these exact example shapes) and the typed entry points here.
//! Callers pad up to the tile shape; padding conventions are chosen so the
//! padded region contributes nothing (zeros for sums, +BIG for argmin).

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::client::{CompiledKernel, PjrtContext};

/// Tile sizes — keep in sync with `python/compile/aot.py::SHAPES`.
pub mod shapes {
    /// MM: (TILE, TILE) × (TILE, TILE) f32 matmul tile (MXU-aligned).
    pub const MM_TILE: usize = 128;
    /// MM grid kernel: full (N, N) product, N = 4 tiles (BlockSpec grid).
    pub const MM_GRID_N: usize = 512;
    /// HG: pixels per histogram kernel call.
    pub const HG_CHUNK: usize = 4096;
    /// HG: bins per channel.
    pub const HG_BINS: usize = 256;
    /// KM: points per assignment call.
    pub const KM_POINTS: usize = 1024;
    /// KM: centroid capacity (pad unused with +BIG coordinates).
    pub const KM_CENTROIDS: usize = 128;
    /// KM: dimensions.
    pub const KM_DIMS: usize = 3;
    /// LR: samples per moment-kernel call.
    pub const LR_CHUNK: usize = 4096;
    /// PC: column block per covariance call.
    pub const PC_BLOCK: usize = 512;
}

/// Artifact base names (files are `<name>.hlo.txt`).
pub const KERNEL_NAMES: [&str; 6] = [
    "matmul",
    "matmul_grid",
    "histogram",
    "kmeans",
    "linreg",
    "pca",
];

/// The non-thread-safe interior: the `xla` crate's handles are `Rc`-based.
struct Inner {
    matmul: CompiledKernel,
    matmul_grid: CompiledKernel,
    histogram: CompiledKernel,
    kmeans: CompiledKernel,
    linreg: CompiledKernel,
    pca: CompiledKernel,
    ctx: PjrtContext,
}

/// All compiled kernels. Construct once, share via `Arc`; every call is
/// serialized behind one mutex.
pub struct KernelSet {
    inner: Mutex<Inner>,
}

// SAFETY: `Inner` holds `Rc`s and raw PJRT handles that are not
// auto-Send/Sync. Every access — including anything that could touch an
// `Rc` refcount — goes through `self.inner.lock()`, so no two threads ever
// observe the interior concurrently; the handles are created and dropped
// inside the same serialized critical sections. The PJRT CPU plugin itself
// holds no thread-affine state (the PJRT C API is documented
// thread-compatible), so moving the serialized interior between OS threads
// is sound.
unsafe impl Send for KernelSet {}
unsafe impl Sync for KernelSet {}

impl KernelSet {
    /// Compile every artifact in `dir`. Errors if any is missing — use
    /// [`KernelSet::try_load`] for the soft probe.
    pub fn load(dir: &Path) -> Result<Arc<KernelSet>> {
        let path = |name: &str| -> PathBuf { dir.join(format!("{name}.hlo.txt")) };
        for name in KERNEL_NAMES {
            if !path(name).exists() {
                bail!(
                    "missing artifact {} — run `make artifacts` first",
                    path(name).display()
                );
            }
        }
        let ctx = PjrtContext::cpu()?;
        let inner = Inner {
            matmul: ctx.compile_file(&path("matmul"))?,
            matmul_grid: ctx.compile_file(&path("matmul_grid"))?,
            histogram: ctx.compile_file(&path("histogram"))?,
            kmeans: ctx.compile_file(&path("kmeans"))?,
            linreg: ctx.compile_file(&path("linreg"))?,
            pca: ctx.compile_file(&path("pca"))?,
            ctx,
        };
        Ok(Arc::new(KernelSet {
            inner: Mutex::new(inner),
        }))
    }

    /// Load from the conventional location (`$MR4R_ARTIFACTS` or
    /// `artifacts/` under the workspace root), or `None` if the artifacts
    /// have not been built.
    pub fn try_load() -> Option<Arc<KernelSet>> {
        let dir = std::env::var("MR4R_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| default_artifact_dir());
        KernelSet::load(&dir).ok()
    }

    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().ctx.platform()
    }

    // ---- Typed entry points (shapes per [`shapes`]) ----

    /// `C = A × B` over one MM_TILE² tile pair.
    pub fn matmul_tile(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        use shapes::MM_TILE as T;
        debug_assert_eq!(a.len(), T * T);
        debug_assert_eq!(b.len(), T * T);
        let inner = self.inner.lock().unwrap();
        inner.matmul.exec_f32(&[(a, &[T, T]), (b, &[T, T])])
    }

    /// Full `C = A × B` over (MM_GRID_N)² operands via the grid-scheduled
    /// Pallas kernel (BlockSpec-staged tiles).
    pub fn matmul_grid(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        use shapes::MM_GRID_N as N;
        debug_assert_eq!(a.len(), N * N);
        debug_assert_eq!(b.len(), N * N);
        let inner = self.inner.lock().unwrap();
        inner.matmul_grid.exec_f32(&[(a, &[N, N]), (b, &[N, N])])
    }

    /// Per-bin counts of one channel chunk (values in `[0, 256)`; pad with
    /// any value ≥ 256 to exclude).
    pub fn histogram_chunk(&self, values: &[f32]) -> Result<Vec<f32>> {
        use shapes::{HG_BINS, HG_CHUNK};
        debug_assert_eq!(values.len(), HG_CHUNK);
        let inner = self.inner.lock().unwrap();
        let out = inner.histogram.exec_f32(&[(values, &[HG_CHUNK])])?;
        debug_assert_eq!(out.len(), HG_BINS);
        Ok(out)
    }

    /// Nearest-centroid assignment for KM_POINTS points over KM_CENTROIDS
    /// centroid slots; returns f32 indices.
    pub fn kmeans_assign(&self, points: &[f32], centroids: &[f32]) -> Result<Vec<f32>> {
        use shapes::{KM_CENTROIDS, KM_DIMS, KM_POINTS};
        debug_assert_eq!(points.len(), KM_POINTS * KM_DIMS);
        debug_assert_eq!(centroids.len(), KM_CENTROIDS * KM_DIMS);
        let inner = self.inner.lock().unwrap();
        inner.kmeans.exec_f32(&[
            (points, &[KM_POINTS, KM_DIMS]),
            (centroids, &[KM_CENTROIDS, KM_DIMS]),
        ])
    }

    /// Moment sums `(Σx, Σy, Σx², Σy², Σxy)` of an LR_CHUNK×2 sample block
    /// (pad with zero rows).
    pub fn linreg_moments(&self, xy: &[f32]) -> Result<Vec<f32>> {
        use shapes::LR_CHUNK;
        debug_assert_eq!(xy.len(), LR_CHUNK * 2);
        let inner = self.inner.lock().unwrap();
        let out = inner.linreg.exec_f32(&[(xy, &[LR_CHUNK, 2])])?;
        debug_assert_eq!(out.len(), 5);
        Ok(out)
    }

    /// Covariance partials `(Σa, Σb, Σab)` of two PC_BLOCK-length row
    /// blocks (pad with zeros).
    pub fn pca_pair(&self, rows: &[f32]) -> Result<Vec<f32>> {
        use shapes::PC_BLOCK;
        debug_assert_eq!(rows.len(), 2 * PC_BLOCK);
        let inner = self.inner.lock().unwrap();
        let out = inner.pca.exec_f32(&[(rows, &[2, PC_BLOCK])])?;
        debug_assert_eq!(out.len(), 3);
        Ok(out)
    }
}

/// `artifacts/` next to the workspace root (where the Makefile puts them).
fn default_artifact_dir() -> PathBuf {
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_name_list_matches_shapes_contract() {
        assert_eq!(KERNEL_NAMES.len(), 6);
        assert!(shapes::MM_TILE.is_power_of_two());
        assert!(shapes::KM_CENTROIDS >= 100, "paper uses 100 clusters");
    }

    #[test]
    fn missing_dir_fails_to_load() {
        assert!(KernelSet::load(Path::new("/nonexistent-dir")).is_err());
    }
}
