//! The PJRT runtime — loads and executes the AOT-compiled JAX/Pallas
//! kernels from the L3 hot path.
//!
//! Build-time Python (`python/compile/aot.py`) lowers each L1/L2 kernel to
//! **HLO text** in `artifacts/*.hlo.txt` (text, not serialized proto: the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos; the
//! text parser reassigns ids — see `/opt/xla-example/README.md`). This
//! module compiles those artifacts once on a CPU PJRT client and exposes
//! typed entry points the benchmark mappers call. Python never runs at
//! job time.

pub mod artifacts;
pub mod client;

pub use artifacts::{KernelSet, KERNEL_NAMES};
pub use client::{CompiledKernel, PjrtContext};
