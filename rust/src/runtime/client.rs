//! PJRT client wrapper: HLO text → compiled executable → typed execution.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU client. Creating a client is expensive (plugin
/// initialization); [`super::artifacts::KernelSet`] holds one per process.
///
/// NOTE: the upstream `xla` crate's handles are `Rc`-based and not
/// `Send`/`Sync`; thread-safety is provided one level up (`KernelSet`
/// serializes every call behind a single mutex).
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Initialize the CPU PJRT client.
    pub fn cpu() -> Result<PjrtContext> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtContext { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_file(&self, path: &Path) -> Result<CompiledKernel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledKernel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// One compiled kernel (not `Send`; see [`PjrtContext`] note).
pub struct CompiledKernel {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl CompiledKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 tensor inputs, returning the flattened f32 outputs
    /// of the (single-tuple) result.
    ///
    /// `inputs` are (data, dims) pairs; the AOT side lowered with
    /// `return_tuple=True`, so the result is always a 1-tuple whose element
    /// is returned flattened (callers know the output dims statically).
    pub fn exec_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = lit
                .reshape(&dims_i64)
                .with_context(|| format!("reshape input to {dims:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing kernel {}", self.name))?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        out.to_vec::<f32>().context("reading f32 output")
    }
}

#[cfg(test)]
mod tests {
    // The PJRT round-trip is covered by `rust/tests/pjrt_runtime.rs`
    // (needs `make artifacts` first); nothing to unit-test without an
    // artifact on disk.
}
