//! The `MapReduce` façade — the paper Figure 2 entry point:
//!
//! ```ignore
//! MapReduce<S, S, I> mrj = new MapReduce<>(mapper, reducer);
//! return mrj.run(input);
//! ```
//!
//! Since the runtime-session redesign this is a thin shim over
//! [`crate::api::Runtime`]/[`crate::api::JobBuilder`]: the façade lazily
//! opens a private session on first run and reuses it for every
//! subsequent `run` on the same instance, so even legacy callers get
//! pool reuse and per-class agent caching for free.

use std::hash::Hash;
use std::sync::{Arc, OnceLock};

use super::config::JobConfig;
use super::runtime::Runtime;
use super::traits::{KeyValue, Mapper, Reducer};
use crate::coordinator::pipeline::FlowMetrics;
use crate::optimizer::agent::OptimizerAgent;
use crate::optimizer::value::RirValue;

/// A configured MapReduce job over inputs `I`, keys `K`, values `V`.
pub struct MapReduce<I, K, V> {
    mapper: Arc<dyn Mapper<I, K, V>>,
    reducer: Arc<dyn Reducer<K, V>>,
    config: JobConfig,
    agent: OptimizerAgent,
    /// The lazily-opened private session (config/agent builders reset it;
    /// they only run before the first `run` in practice).
    session: OnceLock<Runtime>,
}

/// What a run returns beyond the result pairs.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub metrics: FlowMetrics,
}

impl<I, K, V> MapReduce<I, K, V>
where
    I: Clone + Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + RirValue,
    V: RirValue,
{
    /// Create a job with default configuration (paper: "a minimal API ...
    /// exposing only the fundamental API elements").
    pub fn new(
        mapper: impl Mapper<I, K, V> + 'static,
        reducer: impl Reducer<K, V> + 'static,
    ) -> Self {
        MapReduce {
            mapper: Arc::new(mapper),
            reducer: Arc::new(reducer),
            config: JobConfig::new(),
            agent: OptimizerAgent::new(),
            session: OnceLock::new(),
        }
    }

    /// Replace the configuration.
    pub fn with_config(mut self, config: JobConfig) -> Self {
        self.config = config;
        self.session = OnceLock::new();
        self
    }

    /// Share an optimizer agent across jobs (so per-class caching and the
    /// §4.3 timing stats span a whole application, as a real agent would).
    /// New code should share a [`Runtime`] instead.
    pub fn with_agent(mut self, agent: OptimizerAgent) -> Self {
        self.agent = agent;
        self.session = OnceLock::new();
        self
    }

    pub fn config(&self) -> &JobConfig {
        &self.config
    }

    pub fn agent(&self) -> &OptimizerAgent {
        &self.agent
    }

    fn session(&self) -> &Runtime {
        self.session.get_or_init(|| {
            Runtime::with_config_and_agent(self.config.clone(), self.agent.clone())
        })
    }

    /// Run the job, returning the result pairs.
    pub fn run(&self, inputs: &[I]) -> Vec<KeyValue<K, V>> {
        self.run_with_report(inputs).0
    }

    /// Run the job, returning results plus metrics (what the harness uses).
    pub fn run_with_report(&self, inputs: &[I]) -> (Vec<KeyValue<K, V>>, JobReport) {
        let out = self
            .session()
            .job_shared(Arc::clone(&self.mapper), Arc::clone(&self.reducer))
            .run(inputs);
        (out.pairs, out.report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::config::{ExecutionFlow, OptimizeMode};
    use crate::api::reducers::RirReducer;
    use crate::api::traits::Emitter;
    use crate::optimizer::builder::canon;

    #[test]
    fn facade_runs_word_count() {
        let mr: MapReduce<String, String, i64> = MapReduce::new(
            |line: &String, em: &mut dyn Emitter<String, i64>| {
                for w in line.split(' ') {
                    em.emit(w.to_string(), 1);
                }
            },
            RirReducer::new(canon::sum_i64("wc")),
        )
        .with_config(JobConfig::fast().with_threads(2));

        let mut out = mr.run(&["a b a".to_string(), "b a".to_string()]);
        out.sort_by(|x, y| x.key.cmp(&y.key));
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].key.as_str(), out[0].value), ("a", 3));
        assert_eq!((out[1].key.as_str(), out[1].value), ("b", 2));
    }

    #[test]
    fn report_exposes_flow() {
        let mr: MapReduce<String, String, i64> = MapReduce::new(
            |line: &String, em: &mut dyn Emitter<String, i64>| {
                em.emit(line.clone(), 1);
            },
            RirReducer::new(canon::sum_i64("wc-flow")),
        )
        .with_config(JobConfig::fast().with_optimize(OptimizeMode::Auto));
        let (_, report) = mr.run_with_report(&["x".to_string()]);
        assert_eq!(report.metrics.flow, ExecutionFlow::Combine);
    }

    #[test]
    fn shared_agent_caches_across_jobs() {
        let agent = OptimizerAgent::new();
        for _ in 0..3 {
            let mr: MapReduce<String, String, i64> = MapReduce::new(
                |line: &String, em: &mut dyn Emitter<String, i64>| {
                    em.emit(line.clone(), 1);
                },
                RirReducer::new(canon::sum_i64("shared-class")),
            )
            .with_config(JobConfig::fast())
            .with_agent(agent.clone());
            mr.run(&["x".to_string()]);
        }
        let stats = agent.stats();
        assert_eq!(stats.optimized, 1, "one transformation");
        assert_eq!(stats.cache_hits, 2, "two cache hits");
    }

    #[test]
    fn repeat_runs_reuse_the_private_session() {
        let mr: MapReduce<String, String, i64> = MapReduce::new(
            |line: &String, em: &mut dyn Emitter<String, i64>| {
                em.emit(line.clone(), 1);
            },
            RirReducer::new(canon::sum_i64("facade-session")),
        )
        .with_config(JobConfig::fast().with_threads(2));
        mr.run(&["x".to_string()]);
        let spawned = mr.session().spawned_threads();
        mr.run(&["x".to_string()]);
        mr.run(&["x".to_string()]);
        assert_eq!(mr.session().spawned_threads(), spawned);
        // The façade's agent handle shares internals with the session's.
        assert_eq!(mr.agent().stats().cache_hits, 2);
    }
}
