//! Job configuration.
//!
//! Deliberately small: the paper's point (§2.3–2.4) is that MR4J needs *no*
//! manual tuning where Phoenix demands cache sizes and thread counts and
//! Phoenix++ demands compile-time container choices. Everything here has a
//! working default; benchmarks only override `threads` (for the sweep
//! figures) and the optimizer mode (for the ± optimizer comparisons).

use std::sync::Arc;

use crate::govern::{TenantHandle, TenantId};
use crate::memsim::{HeapParams, SimHeap};

/// Whether the agent may rewrite reducers (Figures 7–10 compare
/// `Off` vs `Auto`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizeMode {
    /// Transform every reducer the analysis accepts (the default — the
    /// whole point is zero user involvement).
    Auto,
    /// Never transform: always run the reduce flow (the paper's baseline
    /// MR4J configuration).
    Off,
    /// Transform but suppress compiled fast paths, forcing the interpreted
    /// combiner — the ablation separating "eliminate the reduce phase +
    /// allocation" from "better generated code".
    GenericOnly,
}

/// Which execution flow a job actually took (reported in
/// [`crate::coordinator::pipeline::FlowMetrics`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutionFlow {
    Reduce,
    Combine,
}

impl ExecutionFlow {
    pub fn label(self) -> &'static str {
        match self {
            ExecutionFlow::Reduce => "reduce",
            ExecutionFlow::Combine => "combine",
        }
    }
}

/// Materialization-cache configuration (see [`crate::cache`]). Governs
/// how [`Dataset::cache`](crate::api::plan::Dataset::cache) cut points
/// behave; plans that never mark a cut never touch the cache.
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Whether cut points store/read entries at all. When false,
    /// `Dataset::cache()` is a no-op marker: the prefix recomputes on
    /// every collect (the baseline the cache acceptance tests compare).
    pub enabled: bool,
    /// Simulated-heap occupancy fraction at which inserts start evicting:
    /// when the producing job's heap is at or above
    /// `watermark × total_bytes`, half the cached bytes are released
    /// (LRU-first, cheapest-recompute first among equals).
    pub watermark: f64,
    /// Hard cap on total *hot-tier* cached bytes, independent of heap
    /// pressure — the backstop for disabled-heap (pure-speed) sessions.
    pub max_bytes: u64,
    /// Capacity of the cold spill tier, bytes. Entries evicted from the
    /// hot tier whose (staleness-decayed) recompute cost exceeds their
    /// reload cost are *spilled* here instead of dropped: their simulated
    /// heap cohorts are released (spilled bytes leave the heap), and the
    /// next read reloads them at `bytes × reload_secs_per_byte` instead
    /// of recomputing the prefix. `0` disables the spill tier entirely —
    /// the pre-tiered blind LRU-drop behaviour.
    pub spill_bytes: u64,
    /// Simulated reload latency per spilled byte, seconds. The default
    /// models ~500 MB/s sequential read. Reload traffic is charged to
    /// the reading job's heap (a transient `cache.reload` cohort) so the
    /// GC-pressure metric sees it.
    pub reload_secs_per_byte: f64,
    /// Staleness half-life for the keep/spill/drop heuristic, in cache
    /// LRU ticks: an entry unused for `decay_ticks` reads counts only
    /// half its observed recompute cost. `0` disables decay.
    pub decay_ticks: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        // The spill-tier knobs double as deployment/CI environment
        // switches: `MR4R_CACHE_SPILL_BYTES` sizes (or, at `0`, disables)
        // the cold tier and `MR4R_CACHE_RELOAD_SECS_PER_BYTE` prices it,
        // so the whole suite can run at both extremes without code
        // changes (see the cache-stress CI matrix). Builders still
        // override these per job.
        let spill_bytes = std::env::var("MR4R_CACHE_SPILL_BYTES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(256 << 20);
        let reload_secs_per_byte = std::env::var("MR4R_CACHE_RELOAD_SECS_PER_BYTE")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|v| v.is_finite() && *v >= 0.0)
            .unwrap_or(2e-9);
        CacheConfig {
            enabled: true,
            watermark: 0.85,
            max_bytes: 256 << 20,
            spill_bytes,
            reload_secs_per_byte,
            decay_ticks: 32,
        }
    }
}

/// Per-job runtime configuration.
#[derive(Clone)]
pub struct JobConfig {
    /// Worker threads (paper sweeps 1..#hardware threads).
    pub threads: usize,
    /// Map task granularity: chunks per thread submitted to the pool.
    /// More chunks → better stealing, more queue traffic.
    pub tasks_per_thread: usize,
    /// Optimizer mode.
    pub optimize: OptimizeMode,
    /// Simulated managed heap charged by the collectors (see
    /// [`crate::memsim`]). Use [`SimHeap::disabled`] for pure-speed runs.
    pub heap: Arc<SimHeap>,
    /// Simulated short-lived garbage per map-phase emit, bytes — the
    /// tokenization/boxing scratch a Java mapper produces (e.g. the
    /// `toUpperCase`/`Matcher.group` strings in Figure 2's word count).
    /// Benchmark definitions set this per workload.
    pub scratch_per_emit: u64,
    /// Materialization-cache behaviour at `Dataset::cache()` cut points.
    pub cache: CacheConfig,
    /// Whether plan lowering may consult the session's optimizer feedback
    /// store ([`crate::stats::StatsStore`]) and adapt the physical plan to
    /// statistics measured on earlier runs of the same prefix. Off means
    /// the store is neither read nor written for this job — exactly the
    /// static pre-adaptive behaviour, which keeps adapted ≡ static digest
    /// identity testable. (`OptimizeMode::Off` also bypasses the store
    /// regardless of this switch.)
    pub adaptive: bool,
    /// Tenant this job runs as (see [`crate::govern`]). `None` runs
    /// ungoverned — exactly the pre-governance behaviour.
    pub tenant: Option<TenantId>,
    /// Resolved governance handle for `tenant`, filled in by the owning
    /// [`Runtime`](crate::api::Runtime) when the config is attached to a
    /// plan, job, or stream.
    pub(crate) govern: Option<Arc<TenantHandle>>,
}

impl JobConfig {
    /// Defaults: all cores, auto optimization, accounting heap.
    pub fn new() -> Self {
        JobConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            tasks_per_thread: 4,
            optimize: OptimizeMode::Auto,
            heap: SimHeap::new(HeapParams::default()),
            scratch_per_emit: 0,
            cache: CacheConfig::default(),
            adaptive: true,
            tenant: None,
            govern: None,
        }
    }

    /// Defaults with the memsim disabled — benchmarking the raw runtime.
    pub fn fast() -> Self {
        JobConfig {
            heap: SimHeap::disabled(),
            ..Self::new()
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_optimize(mut self, mode: OptimizeMode) -> Self {
        self.optimize = mode;
        self
    }

    pub fn with_heap(mut self, heap: Arc<SimHeap>) -> Self {
        self.heap = heap;
        self
    }

    pub fn with_scratch_per_emit(mut self, bytes: u64) -> Self {
        self.scratch_per_emit = bytes;
        self
    }

    pub fn with_tasks_per_thread(mut self, t: usize) -> Self {
        self.tasks_per_thread = t.max(1);
        self
    }

    /// Replace the whole cache configuration.
    pub fn with_cache(mut self, cache: CacheConfig) -> Self {
        self.cache = cache;
        self
    }

    /// Toggle `Dataset::cache()` cut points (disabled → every collect
    /// recomputes the prefix; the structure of the plan is unchanged).
    pub fn with_cache_enabled(mut self, enabled: bool) -> Self {
        self.cache.enabled = enabled;
        self
    }

    /// Toggle adaptive re-optimization (see [`crate::stats`]). Disabled →
    /// lowering never consults the feedback store and execution never
    /// records into it: every run takes the static plan.
    pub fn with_adaptive(mut self, adaptive: bool) -> Self {
        self.adaptive = adaptive;
        self
    }

    /// Set the heap-occupancy eviction watermark (fraction of the heap's
    /// `total_bytes`; clamped to `0.0..=1.0`).
    pub fn with_cache_watermark(mut self, watermark: f64) -> Self {
        self.cache.watermark = watermark.clamp(0.0, 1.0);
        self
    }

    /// Set the hard cap on total hot-tier cached bytes.
    pub fn with_cache_max_bytes(mut self, bytes: u64) -> Self {
        self.cache.max_bytes = bytes;
        self
    }

    /// Set the cold spill tier's capacity in bytes (`0` disables the
    /// spill tier: evicted entries are dropped outright, the pre-tiered
    /// baseline behaviour).
    pub fn with_cache_spill_bytes(mut self, bytes: u64) -> Self {
        self.cache.spill_bytes = bytes;
        self
    }

    /// Set the simulated reload latency per spilled byte, seconds
    /// (clamped non-negative). Lower values bias the keep/spill/drop
    /// heuristic toward spilling; `f64::INFINITY` makes every eviction
    /// a drop even with the spill tier enabled.
    pub fn with_cache_reload_cost(mut self, secs_per_byte: f64) -> Self {
        self.cache.reload_secs_per_byte = secs_per_byte.max(0.0);
        self
    }

    /// Set the staleness half-life of the eviction heuristic in cache
    /// LRU ticks (`0` disables decay).
    pub fn with_cache_decay_ticks(mut self, ticks: u64) -> Self {
        self.cache.decay_ticks = ticks;
        self
    }

    /// Run jobs under a registered tenant (see
    /// [`Runtime::register_tenant`](crate::api::Runtime::register_tenant)).
    /// The owning runtime resolves the id to its governance handle when
    /// the config is attached to a plan, job, or stream;
    /// [`Runtime::config_for`](crate::api::Runtime::config_for) returns a
    /// config with the handle already resolved.
    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// The optimizer mode this job actually runs with: the configured
    /// mode, unless the tenant's degrade latch is set (admission under
    /// pressure with `OverloadPolicy::Degrade`), which forces `Off` until
    /// the tenant's next clean admission.
    pub(crate) fn effective_optimize(&self) -> OptimizeMode {
        match &self.govern {
            Some(t) if t.degraded() => OptimizeMode::Off,
            _ => self.optimize,
        }
    }

    /// Whether this job participates in adaptive re-optimization: the
    /// `adaptive` switch, gated by the *effective* optimizer mode so that
    /// `OptimizeMode::Off` (configured or forced by a tenant's degrade
    /// latch) bypasses the feedback store entirely.
    pub(crate) fn adaptive_enabled(&self) -> bool {
        self.adaptive && self.effective_optimize() != OptimizeMode::Off
    }
}

impl Default for JobConfig {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = JobConfig::new();
        assert!(c.threads >= 1);
        assert!(c.tasks_per_thread >= 1);
        assert_eq!(c.optimize, OptimizeMode::Auto);
        assert!(c.heap.enabled());
        assert!(c.adaptive, "adaptive re-optimization defaults on");
    }

    #[test]
    fn adaptive_gate_respects_optimizer_off() {
        let c = JobConfig::fast();
        assert!(c.adaptive_enabled());
        assert!(!c.clone().with_adaptive(false).adaptive_enabled());
        assert!(!c.with_optimize(OptimizeMode::Off).adaptive_enabled());
    }

    #[test]
    fn fast_config_disables_heap() {
        assert!(!JobConfig::fast().heap.enabled());
    }

    #[test]
    fn builders_clamp() {
        let c = JobConfig::new().with_threads(0).with_tasks_per_thread(0);
        assert_eq!(c.threads, 1);
        assert_eq!(c.tasks_per_thread, 1);
        let c = c.with_cache_watermark(7.0);
        assert_eq!(c.cache.watermark, 1.0);
    }

    #[test]
    fn tenant_defaults_off_and_effective_optimize_passthrough() {
        let c = JobConfig::fast();
        assert_eq!(c.tenant, None);
        assert!(c.govern.is_none());
        // Ungoverned configs never override the optimizer mode.
        assert_eq!(c.effective_optimize(), OptimizeMode::Auto);
        let c = c.with_tenant(crate::govern::TenantId(3));
        assert_eq!(c.tenant, Some(crate::govern::TenantId(3)));
    }

    #[test]
    fn cache_defaults_and_builders() {
        let c = JobConfig::new();
        assert!(c.cache.enabled);
        assert!(c.cache.watermark > 0.0 && c.cache.watermark <= 1.0);
        assert!(c.cache.max_bytes > 0);
        let c = c
            .with_cache_enabled(false)
            .with_cache_watermark(0.25)
            .with_cache_max_bytes(1024);
        assert!(!c.cache.enabled);
        assert_eq!(c.cache.watermark, 0.25);
        assert_eq!(c.cache.max_bytes, 1024);
    }

    #[test]
    fn tier_defaults_and_builders() {
        let c = JobConfig::new();
        // The env knobs override the compiled-in defaults, so only pin
        // them down when the environment leaves them alone.
        if std::env::var_os("MR4R_CACHE_SPILL_BYTES").is_none() {
            assert!(c.cache.spill_bytes > 0, "spill tier defaults on");
        }
        if std::env::var_os("MR4R_CACHE_RELOAD_SECS_PER_BYTE").is_none() {
            assert!(c.cache.reload_secs_per_byte > 0.0);
        }
        assert!(c.cache.decay_ticks > 0);
        let c = c
            .with_cache_spill_bytes(0)
            .with_cache_reload_cost(-1.0)
            .with_cache_decay_ticks(0);
        assert_eq!(c.cache.spill_bytes, 0);
        assert_eq!(c.cache.reload_secs_per_byte, 0.0, "reload cost clamps at zero");
        assert_eq!(c.cache.decay_ticks, 0);
    }
}
