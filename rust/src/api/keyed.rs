//! Keyed dataset algebra — **declared** semantics for aggregation.
//!
//! The paper's optimizer (§3) *infers* a combiner from the reducer's
//! bytecode: detection finds the fold, slicing splits it into
//! `initialize`/`combine`/`finalize` (Fig. 4), and the emitter swap runs
//! it during the map phase. That channel only reaches reducers authored
//! in RIR — native closures are opaque and "always take the unoptimized
//! flow". Casper and the Spark keyed algebra show the same semantic facts
//! can simply be *declared* at the API layer. This module is that second
//! channel:
//!
//! * [`Aggregator`] is the user-declared holder triple. Its three methods
//!   map one-to-one onto the paper's Fig. 4 generated methods —
//!   [`Aggregator::init`] ↔ `initialize()` (the holder for values),
//!   [`Aggregator::combine`] ↔ `combine(holder, value)` (the fold body),
//!   [`Aggregator::finish`] ↔ `finalize(holder)` (holder → result) —
//!   plus the [`Aggregator::ASSOCIATIVE`]/[`Aggregator::COMMUTATIVE`]
//!   markers standing in for everything the inferred channel's PDG
//!   analysis has to prove.
//! * [`KeyedDataset`] is the typed keyed view of a lazy pair
//!   [`Dataset`]: [`Dataset::key_by`]/[`Dataset::keyed`] open it;
//!   [`KeyedDataset::map_values`], [`KeyedDataset::group_by_key`],
//!   [`KeyedDataset::count_by_key`], [`KeyedDataset::reduce_by_key`] and
//!   [`KeyedDataset::aggregate_by_key`] record keyed stages; two-input
//!   [`KeyedDataset::join`]/[`KeyedDataset::co_group`] merge keyed plans.
//!
//! At collect time a keyed stage lowers like any reduce barrier (fusion,
//! shard streaming), and the agent's declared channel
//! ([`process_declared`](crate::optimizer::agent::OptimizerAgent::process_declared))
//! decides the flow: an associative + commutative aggregator runs the
//! **in-map combining flow** — workers fold pairs into a sharded table of
//! unboxed typed holders and the shuffle ships *one holder per key*
//! instead of every emitted pair; anything else (or `OptimizeMode::Off`)
//! collects value lists and folds after the barrier. Results are
//! identical either way; `FlowMetrics::{shuffled_pairs, shuffled_holders,
//! shuffled_bytes}` and `FlowMetrics::combiner_source`
//! ([`CombinerSource::Declared`](crate::optimizer::agent::CombinerSource)
//! vs `Inferred`) report which channel fired and what it saved.
//!
//! ```ignore
//! let rt = Runtime::new();
//! let per_region = rt
//!     .dataset(&clicks)                 // (user, url) pairs
//!     .keyed()
//!     .join(rt.dataset(&users).keyed()) // (user, (url, region))
//!     .map(|kv| (kv.value.1.clone(), 1i64))
//!     .keyed()
//!     .reduce_by_key(|a, b| a + b)      // declared associative sum
//!     .collect_sorted();
//! assert_eq!(per_region.metrics().combiner_source,
//!            Some(CombinerSource::Declared));
//! ```

use std::hash::Hash;
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::Arc;

use super::config::{JobConfig, OptimizeMode};
use super::plan::{
    apply_chain, Base, Chain, Dataset, PlanOutput, PlanStage, StageInfo, StageKind, StageToken,
};
use super::source::Feed;
use super::traits::{HeapSized, KeyValue};
use crate::coordinator::collector::shard_count;
use crate::coordinator::pipeline::{concat_shards, run_keyed_sharded_adaptive, KeyedAdaptive};
use crate::coordinator::planner::PlanExec;
use crate::util::hash::{fxhash, FxHashMap};

// ---------------------------------------------------------------------
// The declared holder triple
// ---------------------------------------------------------------------

/// A user-declared combiner: the paper's Fig. 4 `initialize`/`combine`/
/// `finalize` triple, written by hand instead of sliced from bytecode.
///
/// `V` is the emitted value type, `H` the holder (intermediate state),
/// `O` the finished result. The two `const` markers are the declaration
/// the optimizer acts on: the in-map combining flow folds values in
/// whatever order worker emits interleave, so it is granted only when the
/// fold is declared **associative and commutative**. Declaring a marker
/// the fold does not honour yields nondeterministic results — the same
/// contract Spark places on `reduceByKey`.
pub trait Aggregator<V, H, O>: Send + Sync {
    /// `combine` may be regrouped: fold(fold(a, b), c) ≡ fold(a, fold(b, c)).
    const ASSOCIATIVE: bool;
    /// `combine` may be reordered across values of one key.
    const COMMUTATIVE: bool;
    /// Two partial holders for one key may be merged with
    /// [`Aggregator::merge_holders`] — the declaration the streaming
    /// window engine acts on (see [`crate::stream`]): panes keep one
    /// holder per key and windows *merge* pane holders instead of
    /// re-folding every buffered value. Defaults to `false`; declaring
    /// it without overriding `merge_holders` panics at the first merge.
    const MERGEABLE: bool = false;

    /// `initialize()` — a fresh holder (created once per distinct key).
    fn init(&self) -> H;

    /// `combine(holder, value)` — fold one value into the holder.
    fn combine(&self, holder: &mut H, value: V);

    /// `finalize(holder)` — convert the holder into its final form.
    fn finish(&self, holder: H) -> O;

    /// Merge another partial holder into `into` (only called when
    /// [`Aggregator::MERGEABLE`] is declared). Must satisfy
    /// `finish(merge(a, b)) ≡ finish(fold of both holders' values)` —
    /// which is exactly what associativity + commutativity of `combine`
    /// guarantee for holders built from disjoint value sets.
    fn merge_holders(&self, _into: &mut H, _other: H) {
        panic!(
            "aggregator '{}' declares MERGEABLE but does not implement merge_holders",
            self.name()
        );
    }

    /// Stable name for the agent's bookkeeping (the class-name analogue).
    fn name(&self) -> &str {
        "declared-aggregator"
    }
}

/// [`KeyedDataset::reduce_by_key`]'s aggregator: the holder is the
/// running merge of the key's values (`None` until the first one).
pub struct Merge<F> {
    f: F,
}

impl<F> Merge<F> {
    pub fn new(f: F) -> Self {
        Merge { f }
    }
}

impl<V, F> Aggregator<V, Option<V>, V> for Merge<F>
where
    V: Send + Sync,
    F: Fn(V, V) -> V + Send + Sync,
{
    // Declared by `reduce_by_key`'s API contract: the merge function must
    // be associative and commutative (document-level, Spark-style).
    const ASSOCIATIVE: bool = true;
    const COMMUTATIVE: bool = true;
    const MERGEABLE: bool = true;

    fn init(&self) -> Option<V> {
        None
    }

    fn combine(&self, holder: &mut Option<V>, value: V) {
        *holder = Some(match holder.take() {
            None => value,
            Some(acc) => (self.f)(acc, value),
        });
    }

    fn finish(&self, holder: Option<V>) -> V {
        holder.expect("holders are only created on first combine")
    }

    fn merge_holders(&self, into: &mut Option<V>, other: Option<V>) {
        if let Some(v) = other {
            self.combine(into, v);
        }
    }

    fn name(&self) -> &str {
        "keyed.merge"
    }
}

/// [`KeyedDataset::group_by_key`]'s aggregator. Concatenation is
/// associative but **not** commutative (element order matters), so the
/// agent never grants it the combining flow — grouped values always
/// collect as lists, exactly like Spark's `groupByKey` never map-combines.
pub struct Group;

impl<V: Send + Sync> Aggregator<V, Vec<V>, Vec<V>> for Group {
    const ASSOCIATIVE: bool = true;
    const COMMUTATIVE: bool = false;

    fn init(&self) -> Vec<V> {
        Vec::new()
    }

    fn combine(&self, holder: &mut Vec<V>, value: V) {
        holder.push(value);
    }

    fn finish(&self, holder: Vec<V>) -> Vec<V> {
        holder
    }

    fn name(&self) -> &str {
        "keyed.group"
    }
}

/// [`KeyedDataset::count_by_key`]'s aggregator: values are ignored, the
/// holder is the count (the COUNT idiom, declared).
pub struct Count;

impl<V: Send + Sync> Aggregator<V, i64, i64> for Count {
    const ASSOCIATIVE: bool = true;
    const COMMUTATIVE: bool = true;
    const MERGEABLE: bool = true;

    fn init(&self) -> i64 {
        0
    }

    fn combine(&self, holder: &mut i64, _value: V) {
        *holder += 1;
    }

    fn finish(&self, holder: i64) -> i64 {
        holder
    }

    fn merge_holders(&self, into: &mut i64, other: i64) {
        *into += other;
    }

    fn name(&self) -> &str {
        "keyed.count"
    }
}

// ---------------------------------------------------------------------
// Opening a keyed view
// ---------------------------------------------------------------------

impl<'rt, T: 'rt, B: 'rt> Dataset<'rt, T, B> {
    /// Key every element by `f`, keeping the element as the value
    /// (Spark's `keyBy`). Records an element-wise stage, so it fuses into
    /// the downstream keyed barrier like any `map`.
    pub fn key_by<K: 'rt>(
        self,
        f: impl Fn(&T) -> K + Send + Sync + 'rt,
    ) -> KeyedDataset<'rt, K, T, B>
    where
        T: Clone,
    {
        KeyedDataset {
            inner: self.map_named("key_by", move |t| (f(t), t.clone())),
        }
    }
}

impl<'rt, K: 'rt, V: 'rt, B: 'rt> Dataset<'rt, (K, V), B> {
    /// View a pair dataset as keyed. Records no stage — the keyed view is
    /// free; only the aggregations that follow are plan barriers.
    pub fn keyed(self) -> KeyedDataset<'rt, K, V, B> {
        KeyedDataset { inner: self }
    }
}

/// A lazy, typed **keyed** dataflow handle over `(K, V)` pairs — the
/// aggregation surface of the plan API. Built by [`Dataset::key_by`] /
/// [`Dataset::keyed`]; executes nothing until a terminal aggregation's
/// `collect()`. See the [module docs](self) for the declared-semantics
/// contract.
pub struct KeyedDataset<'rt, K, V, B = (K, V)> {
    inner: Dataset<'rt, (K, V), B>,
}

impl<'rt, K: 'rt, V: 'rt, B: 'rt> KeyedDataset<'rt, K, V, B> {
    /// Logical stages recorded so far.
    pub fn stages(&self) -> &[StageInfo] {
        self.inner.stages()
    }

    /// Configuration applied to stages recorded from now on.
    pub fn config(&self) -> &JobConfig {
        self.inner.config()
    }

    pub fn with_config(self, config: JobConfig) -> Self {
        KeyedDataset {
            inner: self.inner.with_config(config),
        }
    }

    pub fn optimize(self, mode: OptimizeMode) -> Self {
        KeyedDataset {
            inner: self.inner.optimize(mode),
        }
    }

    pub fn threads(self, n: usize) -> Self {
        KeyedDataset {
            inner: self.inner.threads(n),
        }
    }

    /// Drop back to the plain pair dataset.
    pub fn into_pairs(self) -> Dataset<'rt, (K, V), B> {
        self.inner
    }

    /// Transform values, keeping keys (element-wise; fuses downstream).
    pub fn map_values<W: 'rt>(
        self,
        f: impl Fn(&V) -> W + Send + Sync + 'rt,
    ) -> KeyedDataset<'rt, K, W, B>
    where
        K: Clone,
    {
        KeyedDataset {
            inner: self
                .inner
                .map_named("map_values", move |p: &(K, V)| (p.0.clone(), f(&p.1))),
        }
    }

    /// The general keyed barrier: fold each key's values through a
    /// declared [`Aggregator`]. This is where the plan records a
    /// [`StageKind::KeyedAggregate`] stage; whether it runs the in-map
    /// combining flow is the agent's decision at collect time.
    pub fn aggregate_by_key<H, O, A>(self, agg: A) -> Dataset<'rt, KeyValue<K, O>>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + Sync + HeapSized,
        V: Clone + Send + Sync + HeapSized,
        H: Send + HeapSized + 'rt,
        O: Send + HeapSized + 'rt,
        A: Aggregator<V, H, O> + 'rt,
    {
        let Dataset {
            rt,
            base,
            chain,
            mut stages,
            chain_start,
            config,
            probes,
            adapt_log,
            ..
        } = self.inner.flush_pending();
        let index = stages.len();
        let agg = Arc::new(agg);
        // Keyed stages identify by their aggregator `Arc` address (reuse
        // the same handle across plans for matching prefix fingerprints,
        // exactly like `map_reduce_shared`); the planner maps it to a
        // session ordinal only if the plan actually marks a cache cut.
        let token = StageToken::Address(fxhash(&(Arc::as_ptr(&agg) as *const () as usize)));
        stages.push(StageInfo {
            kind: StageKind::KeyedAggregate,
            name: agg.name().to_string(),
            optimize: config.optimize,
            token: Some(token),
        });
        let stage = KeyedStage {
            base,
            chain,
            chain_range: chain_start..index,
            index,
            agg,
            cfg: config.clone(),
            _out: PhantomData,
        };
        Dataset {
            rt,
            base: Base::Stage(Box::new(stage)),
            chain: Chain::direct(),
            chain_start: stages.len(),
            stages,
            config,
            pending: Vec::new(),
            probes,
            adapt_log,
        }
    }

    /// Fold each key's values with an **associative, commutative** merge
    /// (Spark's `reduceByKey`). The declaration is the API contract; the
    /// optimizer acts on it without ever seeing the closure's body — the
    /// exact capability the inferred channel denies native closures.
    pub fn reduce_by_key(
        self,
        merge: impl Fn(V, V) -> V + Send + Sync + 'rt,
    ) -> Dataset<'rt, KeyValue<K, V>>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + Sync + HeapSized,
        V: Clone + Send + Sync + HeapSized + 'rt,
    {
        self.aggregate_by_key(Merge::new(merge))
    }

    /// Collect each key's values into a list (Spark's `groupByKey`;
    /// never map-combines — see [`Group`]).
    pub fn group_by_key(self) -> Dataset<'rt, KeyValue<K, Vec<V>>>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + Sync + HeapSized,
        V: Clone + Send + Sync + HeapSized + 'rt,
    {
        self.aggregate_by_key(Group)
    }

    /// Count values per key (the COUNT idiom, declared).
    pub fn count_by_key(self) -> Dataset<'rt, KeyValue<K, i64>>
    where
        B: Send + Sync,
        K: Hash + Eq + Clone + Send + Sync + HeapSized,
        V: Clone + Send + Sync + HeapSized,
    {
        self.aggregate_by_key(Count)
    }

    /// Assign each pair to a **tumbling** (non-overlapping) event-time
    /// window of `size` ticks, using `ts` to extract a value's timestamp.
    /// The windowed view aggregates per `(window, key)` — see
    /// [`Windowed`](crate::stream::Windowed) and the streaming twin on
    /// [`KeyedStream`](crate::stream::KeyedStream).
    pub fn window_tumbling(
        self,
        size: u64,
        ts: impl Fn(&V) -> u64 + Send + Sync + 'rt,
    ) -> crate::stream::Windowed<'rt, K, V, B> {
        crate::stream::Windowed::over(self.inner, crate::stream::WindowSpec::tumbling(size), ts)
    }

    /// Assign each pair to every **sliding** window of `size` ticks that
    /// covers its timestamp, windows advancing by `slide` ticks
    /// (`size % slide == 0`). Pairs land in one pane of width `slide`;
    /// each window spans `size / slide` consecutive panes, so a mergeable
    /// aggregator folds each value once and windows merge pane holders.
    pub fn window_sliding(
        self,
        size: u64,
        slide: u64,
        ts: impl Fn(&V) -> u64 + Send + Sync + 'rt,
    ) -> crate::stream::Windowed<'rt, K, V, B> {
        crate::stream::Windowed::over(
            self.inner,
            crate::stream::WindowSpec::sliding(size, slide),
            ts,
        )
    }

    /// Two-input co-group: for every key present in either input, the
    /// pair of value lists `(Vec<V>, Vec<V2>)`. Both upstream plans run
    /// as sub-plans (their reports merge into this plan's report); the
    /// grouped sides hash-merge by key.
    ///
    /// The merge itself records no stage metrics, so on a plan that
    /// *ends* here, [`PlanOutput::metrics`] reports the last executed
    /// sub-stage (the right input's grouping). Chain an aggregation
    /// after the co-group for a meaningful final-stage report.
    pub fn co_group<V2: 'rt, B2: 'rt>(
        self,
        other: KeyedDataset<'rt, K, V2, B2>,
    ) -> Dataset<'rt, KeyValue<K, (Vec<V>, Vec<V2>)>>
    where
        B: Send + Sync,
        B2: Send + Sync,
        K: Hash + Eq + Clone + Send + Sync + HeapSized + 'rt,
        V: Clone + Send + Sync + HeapSized + 'rt,
        V2: Clone + Send + Sync + HeapSized + 'rt,
    {
        let rt = self.inner.rt;
        let config = self.inner.config.clone();
        let optimize = config.optimize;
        let stage = CoGroupStage {
            left: Box::new(move || self.group_by_key().collect()),
            right: Box::new(move || other.group_by_key().collect()),
            n_shards: shard_count(config.threads),
        };
        Dataset {
            rt,
            base: Base::Stage(Box::new(stage)),
            chain: Chain::direct(),
            stages: vec![StageInfo {
                kind: StageKind::CoGroup,
                name: "co_group".to_string(),
                optimize,
                // A co-group plan owns no source of its own (both inputs
                // run as sub-plans), so it is never a cacheable root.
                token: None,
            }],
            chain_start: 1,
            config,
            // Each co-group input is its own sub-plan: it flushes, probes,
            // and records its filters and stages under its own prefix
            // fingerprints when it collects.
            pending: Vec::new(),
            probes: Vec::new(),
            adapt_log: Vec::new(),
        }
    }

    /// Two-input inner join: one output pair per matching `(V, V2)`
    /// combination per key — a co-group with the cross product expanded
    /// through a fused `flat_map`. (The second `Dataset` type parameter
    /// is the co-group barrier the expansion hangs off — an
    /// implementation detail, as everywhere in the plan API.) As with
    /// [`KeyedDataset::co_group`], a plan that ends at the join reports
    /// sub-stage metrics; aggregate after it for a final-stage report.
    pub fn join<V2: 'rt, B2: 'rt>(
        self,
        other: KeyedDataset<'rt, K, V2, B2>,
    ) -> Dataset<'rt, KeyValue<K, (V, V2)>, KeyValue<K, (Vec<V>, Vec<V2>)>>
    where
        B: Send + Sync,
        B2: Send + Sync,
        K: Hash + Eq + Clone + Send + Sync + HeapSized + 'rt,
        V: Clone + Send + Sync + HeapSized + 'rt,
        V2: Clone + Send + Sync + HeapSized + 'rt,
    {
        self.co_group(other).flat_map_named(
            "join",
            |kv: &KeyValue<K, (Vec<V>, Vec<V2>)>, sink: &mut dyn FnMut(KeyValue<K, (V, V2)>)| {
                for left in &kv.value.0 {
                    for right in &kv.value.1 {
                        sink(KeyValue::new(
                            kv.key.clone(),
                            (left.clone(), right.clone()),
                        ));
                    }
                }
            },
        )
    }
}

// ---------------------------------------------------------------------
// Physical execution
// ---------------------------------------------------------------------

/// A recorded keyed aggregation stage, built while all types are still
/// concrete (the keyed analogue of `plan.rs`'s `ReduceStage`).
struct KeyedStage<'rt, B, K, V, H, O, A> {
    base: Base<'rt, B>,
    chain: Chain<'rt, B, (K, V)>,
    /// Logical indices of the chain's element-wise stages.
    chain_range: Range<usize>,
    /// Logical index of this keyed stage.
    index: usize,
    agg: Arc<A>,
    cfg: JobConfig,
    _out: PhantomData<fn() -> (H, O)>,
}

impl<'rt, B, K, V, H, O, A> PlanStage<'rt, KeyValue<K, O>> for KeyedStage<'rt, B, K, V, H, O, A>
where
    B: Send + Sync + 'rt,
    K: Hash + Eq + Clone + Send + Sync + HeapSized + 'rt,
    V: Clone + Send + Sync + HeapSized + 'rt,
    H: Send + HeapSized + 'rt,
    O: Send + HeapSized + 'rt,
    A: Aggregator<V, H, O> + 'rt,
{
    fn execute(self: Box<Self>, exec: &mut PlanExec<'rt>) -> Vec<Vec<KeyValue<K, O>>> {
        let KeyedStage {
            base,
            chain,
            chain_range,
            index,
            agg,
            cfg,
            ..
        } = *self;
        let fuse = exec.chain_fused(&chain_range);
        let agg: &A = &agg;
        // The upstream chain composed under the keyed stage's pair
        // stream: barrier elements flow through the element-wise ops and
        // the resulting pairs are cloned out to the fold (fusion, keyed
        // edition — the counterpart of `plan.rs`'s `FusedMapper`).
        let fused_impl = |b: &B, sink: &mut dyn FnMut(K, V)| match &chain {
            Chain::Direct { by_ref, .. } => {
                let p = by_ref(b);
                sink(p.0.clone(), p.1.clone());
            }
            Chain::Ops { op } => op(b, &mut |p: &(K, V)| sink(p.0.clone(), p.1.clone())),
        };
        let fused_pairs: &(dyn Fn(&B, &mut dyn FnMut(K, V)) + Sync) = &fused_impl;
        // Pair extraction over an already-staged `(K, V)` buffer (the
        // unfused paths).
        let staged_impl = |p: &(K, V), sink: &mut dyn FnMut(K, V)| sink(p.0.clone(), p.1.clone());
        let staged_pairs: &(dyn Fn(&(K, V), &mut dyn FnMut(K, V)) + Sync) = &staged_impl;
        match base {
            Base::Source(mut src) => {
                if fuse {
                    run_keyed_stage(exec, fused_pairs, agg, src.feed(), &cfg, 0, index)
                } else {
                    let hint = src.len_hint();
                    let staged = apply_chain(src.feed(), &chain, hint);
                    let staged_len = staged.len() as u64;
                    run_keyed_stage(
                        exec,
                        staged_pairs,
                        agg,
                        Feed::Slice(&staged),
                        &cfg,
                        staged_len,
                        index,
                    )
                }
            }
            Base::Stage(upstream) => {
                let shards = upstream.execute(exec);
                let stream = exec.stream_input(index);
                match (stream, fuse) {
                    (true, true) => {
                        let mut iter = shards.into_iter();
                        let feed: Feed<'_, B> = Feed::Stream(Box::new(move || iter.next()));
                        run_keyed_stage(exec, fused_pairs, agg, feed, &cfg, 0, index)
                    }
                    (true, false) => {
                        let total: usize = shards.iter().map(Vec::len).sum();
                        let mut iter = shards.into_iter();
                        let feed: Feed<'_, B> = Feed::Stream(Box::new(move || iter.next()));
                        let staged = apply_chain(feed, &chain, Some(total));
                        let staged_len = staged.len() as u64;
                        run_keyed_stage(
                            exec,
                            staged_pairs,
                            agg,
                            Feed::Slice(&staged),
                            &cfg,
                            staged_len,
                            index,
                        )
                    }
                    (false, fused_chain) => {
                        let handoff = concat_shards(shards);
                        let mut materialized = handoff.len() as u64;
                        if fused_chain {
                            run_keyed_stage(
                                exec,
                                fused_pairs,
                                agg,
                                Feed::Slice(&handoff),
                                &cfg,
                                materialized,
                                index,
                            )
                        } else {
                            let staged =
                                apply_chain(Feed::Slice(&handoff), &chain, Some(handoff.len()));
                            materialized += staged.len() as u64;
                            run_keyed_stage(
                                exec,
                                staged_pairs,
                                agg,
                                Feed::Slice(&staged),
                                &cfg,
                                materialized,
                                index,
                            )
                        }
                    }
                }
            }
        }
    }
}

/// Run one physical keyed stage, recording its metrics (the keyed twin of
/// `plan.rs`'s `run_stage`). Under adaptive re-optimization the stage
/// receives the lowering's hints for its logical index, observes key
/// skew into [`FlowMetrics::skew`](crate::coordinator::pipeline::FlowMetrics)
/// when the aggregator's holders can merge, and hands over
/// [`Aggregator::merge_holders`] so a split hot key's partial holders
/// re-merge after the barrier.
fn run_keyed_stage<'rt, I, K, V, H, O, A>(
    exec: &mut PlanExec<'rt>,
    pairs: &(dyn Fn(&I, &mut dyn FnMut(K, V)) + Sync),
    agg: &A,
    feed: Feed<'_, I>,
    cfg: &JobConfig,
    materialized_in: u64,
    index: usize,
) -> Vec<Vec<KeyValue<K, O>>>
where
    I: Send + Sync,
    K: Hash + Eq + Clone + Send + Sync + HeapSized,
    V: Send + HeapSized,
    H: Send + HeapSized,
    O: Send + HeapSized,
    A: Aggregator<V, H, O>,
{
    let adaptive = cfg.adaptive_enabled();
    let merge_impl = |h: &mut H, o: H| agg.merge_holders(h, o);
    let ctx = KeyedAdaptive {
        adapt: if adaptive { exec.adaptive_for(index) } else { None },
        observe: adaptive && A::MERGEABLE,
        merge: if A::MERGEABLE { Some(&merge_impl) } else { None },
    };
    let (shards, mut metrics) = run_keyed_sharded_adaptive(
        exec.pool,
        agg.name(),
        A::ASSOCIATIVE,
        A::COMMUTATIVE,
        pairs,
        || agg.init(),
        |h: &mut H, v: V| agg.combine(h, v),
        |h: H| agg.finish(h),
        feed,
        cfg,
        exec.agent,
        ctx,
    );
    metrics.materialized_in = materialized_in;
    exec.note_materialized(materialized_in);
    exec.push_metrics(metrics);
    shards
}

/// A two-input co-group barrier. Each side is a deferred sub-plan
/// (`group_by_key().collect()` over the session runtime); execution runs
/// both, absorbs their reports, and hash-merges the grouped outputs.
struct CoGroupStage<'rt, K, V, V2> {
    left: Box<dyn FnOnce() -> PlanOutput<KeyValue<K, Vec<V>>> + 'rt>,
    right: Box<dyn FnOnce() -> PlanOutput<KeyValue<K, Vec<V2>>> + 'rt>,
    /// Output shard count (power of two). The merged table is re-sharded
    /// by key hash so a downstream streamed stage parallelizes — one big
    /// shard would hand the whole co-group output to a single worker.
    n_shards: usize,
}

impl<'rt, K, V, V2> PlanStage<'rt, KeyValue<K, (Vec<V>, Vec<V2>)>> for CoGroupStage<'rt, K, V, V2>
where
    K: Hash + Eq + 'rt,
    V: 'rt,
    V2: 'rt,
{
    fn execute(
        self: Box<Self>,
        exec: &mut PlanExec<'rt>,
    ) -> Vec<Vec<KeyValue<K, (Vec<V>, Vec<V2>)>>> {
        let CoGroupStage {
            left,
            right,
            n_shards,
        } = *self;
        let PlanOutput {
            items: left,
            report: left_report,
        } = left();
        let PlanOutput {
            items: right,
            report: right_report,
        } = right();
        exec.absorb(left_report);
        exec.absorb(right_report);
        // Hash-merge (the co-group's working table, analogous to a
        // collector — not charged as a plan-level materialization).
        let mut table: FxHashMap<K, (Vec<V>, Vec<V2>)> = FxHashMap::default();
        for kv in left {
            table.entry(kv.key).or_default().0 = kv.value;
        }
        for kv in right {
            table.entry(kv.key).or_default().1 = kv.value;
        }
        // Re-shard by key hash (high bits, like every collector) so the
        // consumer's streamed map phase has chunks to balance across
        // workers.
        let n = n_shards.next_power_of_two().max(1);
        let mut shards: Vec<Vec<KeyValue<K, (Vec<V>, Vec<V2>)>>> =
            (0..n).map(|_| Vec::new()).collect();
        for (k, groups) in table {
            let s = (fxhash(&k) >> 48) as usize & (n - 1);
            shards[s].push(KeyValue::new(k, groups));
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::config::ExecutionFlow;
    use crate::api::runtime::Runtime;
    use crate::optimizer::agent::CombinerSource;

    fn rt() -> Runtime {
        Runtime::with_config(JobConfig::fast().with_threads(2))
    }

    fn pairs() -> Vec<(String, i64)> {
        vec![
            ("a".into(), 1),
            ("b".into(), 10),
            ("a".into(), 2),
            ("c".into(), 100),
            ("b".into(), 20),
            ("a".into(), 4),
        ]
    }

    #[test]
    fn reduce_by_key_sums_and_reports_declared() {
        let rt = rt();
        let data = pairs();
        let out = rt
            .dataset(&data)
            .keyed()
            .reduce_by_key(|a, b| a + b)
            .collect_sorted();
        assert_eq!(
            out.items,
            vec![
                KeyValue::new("a".to_string(), 7),
                KeyValue::new("b".to_string(), 30),
                KeyValue::new("c".to_string(), 100),
            ]
        );
        assert_eq!(out.metrics().flow, ExecutionFlow::Combine);
        assert_eq!(out.metrics().combiner_source, Some(CombinerSource::Declared));
        assert_eq!(out.metrics().shuffled_pairs, 0);
        assert_eq!(out.metrics().shuffled_holders, 3);
        assert_eq!(rt.agent().stats().declared_accepted, 1);
    }

    #[test]
    fn group_by_key_keeps_the_list_flow() {
        let rt = rt();
        let data = pairs();
        let out = rt
            .dataset(&data)
            .keyed()
            .group_by_key()
            .collect_sorted();
        assert_eq!(out.metrics().flow, ExecutionFlow::Reduce);
        assert_eq!(out.metrics().combiner_source, None);
        assert!(out
            .metrics()
            .fallback_reason
            .as_deref()
            .unwrap()
            .contains("non-commutative"));
        let mut a_vals = out.items[0].value.clone();
        a_vals.sort_unstable();
        assert_eq!((out.items[0].key.as_str(), a_vals), ("a", vec![1, 2, 4]));
        assert_eq!(rt.agent().stats().declared_rejected, 1);
    }

    #[test]
    fn key_by_map_values_count_by_key_compose() {
        let rt = rt();
        let words: Vec<String> = ["spark", "flink", "spark", "mr4r", "spark"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let out = rt
            .dataset(&words)
            .key_by(|w| w.len() as i64)
            .map_values(|w| w.clone())
            .count_by_key()
            .collect_sorted();
        assert_eq!(
            out.items,
            vec![KeyValue::new(4, 1), KeyValue::new(5, 4)]
        );
        assert_eq!(out.report.stage_metrics.len(), 1, "one keyed barrier ran");
    }

    #[test]
    fn join_and_co_group_merge_two_plans() {
        let rt = rt();
        let clicks: Vec<(String, String)> = vec![
            ("u1".into(), "/home".into()),
            ("u2".into(), "/buy".into()),
            ("u1".into(), "/buy".into()),
            ("u3".into(), "/home".into()),
        ];
        let users: Vec<(String, String)> = vec![
            ("u1".into(), "eu".into()),
            ("u2".into(), "us".into()),
        ];
        let joined = rt
            .dataset(&clicks)
            .keyed()
            .join(rt.dataset(&users).keyed())
            .collect();
        let mut rows: Vec<(String, (String, String))> = joined
            .iter()
            .map(|kv| (kv.key.clone(), kv.value.clone()))
            .collect();
        rows.sort();
        assert_eq!(
            rows,
            vec![
                ("u1".to_string(), ("/buy".to_string(), "eu".to_string())),
                ("u1".to_string(), ("/home".to_string(), "eu".to_string())),
                ("u2".to_string(), ("/buy".to_string(), "us".to_string())),
            ],
            "inner join drops the unmatched u3"
        );
        // Both sub-plans' stage metrics surface in the outer report.
        assert_eq!(joined.report.stage_metrics.len(), 2);

        let cg = rt
            .dataset(&clicks)
            .keyed()
            .co_group(rt.dataset(&users).keyed())
            .collect_sorted();
        assert_eq!(cg.items.len(), 3, "co-group keeps unmatched keys");
        let u3 = cg.items.iter().find(|kv| kv.key == "u3").unwrap();
        assert_eq!(u3.value.0.len(), 1);
        assert!(u3.value.1.is_empty());
    }

    #[test]
    fn keyed_stage_streams_a_reduce_handoff() {
        let rt = rt();
        let data = pairs();
        let out = rt
            .dataset(&data)
            .keyed()
            .reduce_by_key(|a, b| a + b)
            .map(|kv| (kv.value % 10, 1i64))
            .keyed()
            .count_by_key()
            .collect_sorted();
        // Sums 7, 30, 100 → last digits 7, 0, 0.
        assert_eq!(
            out.items,
            vec![KeyValue::new(0, 2), KeyValue::new(7, 1)]
        );
        assert_eq!(out.report.streamed_handoffs, 1);
        assert_eq!(out.report.fused_ops, 1);
        assert_eq!(out.report.materialized_pairs, 0);
    }
}
