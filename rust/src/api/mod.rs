//! The public MR4R programming surface — the Rust rendering of paper
//! Figure 2's API (`Mapper`, `Reducer`, `Emitter`, `MapReduce`).
//!
//! Design principles follow the paper's §2.4 list: a minimal API close to
//! the original Google formulation, no manual tuning knobs required, and an
//! optimizer that engages *transparently* — user code defines `map` and
//! `reduce` only; whether the runtime executes the reduce flow or the
//! combining flow is decided by the [`crate::optimizer::agent`], never by
//! the application.

pub mod config;
pub mod job;
pub mod reducers;
pub mod traits;

pub use config::{ExecutionFlow, JobConfig, OptimizeMode};
pub use job::{JobReport, MapReduce};
pub use reducers::RirReducer;
pub use traits::{Emitter, HeapSized, KeyKind, KeyValue, Mapper, Reducer, VecEmitter};
