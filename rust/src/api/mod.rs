//! The public MR4R programming surface — the Rust rendering of paper
//! Figure 2's API (`Mapper`, `Reducer`, `Emitter`), grown into a session
//! runtime.
//!
//! Design principles follow the paper's §2.4 list: a minimal API close to
//! the original Google formulation, no manual tuning knobs required, and an
//! optimizer that engages *transparently* — user code defines `map` and
//! `reduce` only; whether the runtime executes the reduce flow or the
//! combining flow is decided by the [`crate::optimizer::agent`], never by
//! the application.
//!
//! Three entry points share one engine:
//!
//! * [`Dataset`] — the lazy dataflow API ([`Runtime::dataset`]): record a
//!   plan of `map`/`filter`/`flat_map`/`map_reduce` stages, execute on
//!   `collect()` after the agent's whole-plan pass has fused element-wise
//!   stages and arranged reduce handoffs to stream (see [`plan`]). Its
//!   keyed view ([`KeyedDataset`], via `key_by`/`keyed`) adds the
//!   declared-semantics algebra — `reduce_by_key`, `aggregate_by_key`
//!   with a user [`Aggregator`] triple, `group_by_key`, `count_by_key`,
//!   and two-input `join`/`co_group` (see [`keyed`]).
//! * [`Runtime`]/[`JobBuilder`] — the eager session API: a persistent
//!   **multi-tenant** worker pool (concurrent jobs from many driver
//!   threads share the workers fairly; see [`Runtime::spawn_plan`]), a
//!   shared optimizer agent, streaming [`InputSource`]s, output ordering
//!   contracts, and job chaining via [`Runtime::pipeline`]. Now a thin
//!   shim over one-stage plans.
//! * [`MapReduce`] — the paper's one-shot façade, kept as a thin shim
//!   over a private session.

pub mod config;
pub mod job;
pub mod keyed;
pub mod plan;
pub mod reducers;
pub mod runtime;
pub mod source;
pub mod traits;

pub use config::{CacheConfig, ExecutionFlow, JobConfig, OptimizeMode};
pub use job::{JobReport, MapReduce};
pub use keyed::{Aggregator, KeyedDataset};
pub use plan::{Dataset, PlanOutput, PlanReport, StageInfo, StageKind, StageToken};
pub use reducers::RirReducer;
pub use runtime::{JobBuilder, JobOutput, Pipeline, PlanHandle, Runtime};
pub use source::{ChunkedSource, Feed, InputSource, IterSource};
pub use traits::{Emitter, HeapSized, KeyKind, KeyValue, Mapper, Reducer, VecEmitter};
